//! NaN-safe total orderings for floats.
//!
//! `f32::total_cmp` alone is not enough for "NaN sorts last": IEEE-754
//! total order places *negative* NaN below -inf, so a poisoned slice
//! would sort NaNs to the *front* depending on the sign bit. These
//! comparators treat every NaN (either sign) as the greatest element,
//! so `sort_by(nan_last_*)` pushes all NaNs to the tail and the finite
//! prefix is ordered by `total_cmp` — deterministic, never panics.

use std::cmp::Ordering;

/// Ascending order, any NaN last.
pub fn nan_last_f32(a: &f32, b: &f32) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => a.total_cmp(b),
    }
}

/// Ascending order, any NaN last.
pub fn nan_last_f64(a: &f64, b: &f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => a.total_cmp(b),
    }
}

/// Descending order, any NaN last (a raw descending `b.total_cmp(a)`
/// would sort *positive* NaNs to the front).
pub fn nan_last_desc_f64(a: &f64, b: &f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => b.total_cmp(a),
    }
}

/// Descending by absolute value, any NaN last (|NaN| is NaN, so the
/// naive `b.abs().total_cmp(&a.abs())` would sort NaNs *first* in a
/// descending sort).
pub fn nan_last_desc_abs_f32(a: &f32, b: &f32) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => b.abs().total_cmp(&a.abs()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nan_last_f32_sorts_nans_to_tail() {
        let mut v = vec![f32::NAN, 2.0, -f32::NAN, -1.0, f32::INFINITY, f32::NEG_INFINITY, 0.0];
        v.sort_by(nan_last_f32);
        assert_eq!(&v[..5], &[f32::NEG_INFINITY, -1.0, 0.0, 2.0, f32::INFINITY]);
        assert!(v[5].is_nan() && v[6].is_nan());
    }

    #[test]
    fn nan_last_f64_sorts_nans_to_tail() {
        // -NaN is the regression case: raw total_cmp puts it before -inf
        let mut v = vec![-f64::NAN, 1.5, f64::NAN, -3.0, 0.25];
        v.sort_by(nan_last_f64);
        assert_eq!(&v[..3], &[-3.0, 0.25, 1.5]);
        assert!(v[3].is_nan() && v[4].is_nan());
    }

    #[test]
    fn desc_f64_orders_descending_with_nans_last() {
        let mut v = vec![0.5f64, f64::NAN, -4.0, 2.0, -f64::NAN, f64::INFINITY];
        v.sort_by(nan_last_desc_f64);
        assert_eq!(&v[..4], &[f64::INFINITY, 2.0, 0.5, -4.0]);
        assert!(v[4].is_nan() && v[5].is_nan());
    }

    #[test]
    fn desc_abs_orders_by_magnitude_with_nans_last() {
        let mut v = vec![0.5f32, f32::NAN, -4.0, 2.0, -f32::NAN, -0.25];
        v.sort_by(nan_last_desc_abs_f32);
        assert_eq!(&v[..4], &[-4.0, 2.0, 0.5, -0.25]);
        assert!(v[4].is_nan() && v[5].is_nan());
    }

    #[test]
    fn comparators_are_total_on_poisoned_input() {
        // sort_by panics on inconsistent comparators in debug builds;
        // surviving a fully poisoned slice is the regression guard
        let mut v = vec![f32::NAN; 8];
        v.sort_by(nan_last_f32);
        v.sort_by(nan_last_desc_abs_f32);
        assert_eq!(v.len(), 8);
    }
}
