//! Seeded property-testing substrate (no `proptest` available offline).
//!
//! `forall(cases, gen, prop)` runs `prop` on `cases` random inputs drawn by
//! `gen`; on failure it panics with the *case seed*, which can be pinned via
//! the `CIDERTF_PROP_SEED` environment variable to reproduce a single case.

use crate::util::rng::Rng;

/// Run a property over `cases` generated inputs.
///
/// `gen` receives a per-case RNG; `prop` returns `Err(reason)` to fail.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T, &mut Rng) -> Result<(), String>,
) {
    let pinned: Option<u64> = std::env::var("CIDERTF_PROP_SEED").ok().and_then(|s| s.parse().ok());
    let base = 0xC1DE_21F0_u64;
    let seeds: Vec<u64> = match pinned {
        Some(s) => vec![s],
        None => (0..cases as u64).map(|i| base.wrapping_add(i)).collect(),
    };
    for seed in seeds {
        let mut g = Rng::new(seed);
        let input = gen(&mut g);
        let mut check_rng = g.split(1);
        if let Err(msg) = prop(&input, &mut check_rng) {
            panic!(
                "property '{name}' failed (CIDERTF_PROP_SEED={seed} to reproduce)\n  input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

/// Convenience: assert two f32 slices are close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let denom = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() / denom > tol {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            "sum-commutes",
            25,
            |g| (g.below(100) as i64, g.below(100) as i64),
            |&(a, b), _| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "CIDERTF_PROP_SEED")]
    fn failing_property_reports_seed() {
        forall(
            "always-fails",
            3,
            |g| g.below(10),
            |_, _| Err("expected failure".into()),
        );
    }

    #[test]
    fn assert_close_catches_divergence() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }
}
