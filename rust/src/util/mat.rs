//! Dense row-major f32 matrix substrate (no ndarray/BLAS offline).
//!
//! Factor matrices are tall-skinny (`I x R`, R <= 64), so the kernels here
//! are written for that regime: row-major layout, ikj GEMM loops that
//! vectorize well, and allocation-free `*_into` variants for the engine's
//! hot paths.

use crate::util::rng::Rng;

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// i.i.d. uniform entries in `[0, scale)` — the standard non-negative
    /// init for EHR tensor factorization.
    pub fn rand_uniform(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.uniform_f32() * scale);
        }
        Mat { rows, cols, data }
    }

    pub fn rand_normal(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.normal_f32() * std);
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// `self += alpha * other` (the engine's most-executed loop).
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// `self = alpha * self`.
    pub fn scale(&mut self, alpha: f32) {
        self.data.iter_mut().for_each(|x| *x *= alpha);
    }

    /// `self -= other`.
    pub fn sub_assign(&mut self, other: &Mat) {
        self.axpy(-1.0, other);
    }

    /// `self += other`.
    pub fn add_assign(&mut self, other: &Mat) {
        self.axpy(1.0, other);
    }

    /// Elementwise product accumulate: `self *= other`.
    pub fn hadamard_assign(&mut self, other: &Mat) {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a *= b;
        }
    }

    /// Squared Frobenius norm.
    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn frob(&self) -> f64 {
        self.frob_sq().sqrt()
    }

    /// l1 norm of all entries (sign-compressor scale).
    pub fn l1(&self) -> f64 {
        self.data.iter().map(|&x| x.abs() as f64).sum()
    }

    /// Squared Frobenius norm of `self - other` without allocating.
    pub fn dist_sq(&self, other: &Mat) -> f64 {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }

    /// `C = self * other` (`[m,k] x [k,n]`), ikj loop order.
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut c = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut c);
        c
    }

    pub fn matmul_into(&self, other: &Mat, c: &mut Mat) {
        assert_eq!(self.cols, other.rows);
        assert_eq!((c.rows, c.cols), (self.rows, other.cols));
        c.fill(0.0);
        let n = other.cols;
        for i in 0..self.rows {
            let crow = &mut c.data[i * n..(i + 1) * n];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[k * n..(k + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += a * bv;
                }
            }
        }
    }

    /// `C = self * other^T` (`[m,k] x [n,k]^T`), row-dot-row — cache friendly.
    pub fn matmul_transb(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut c = Mat::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let arow = self.row(i);
            for j in 0..other.rows {
                let brow = other.row(j);
                let mut s = 0.0f32;
                for (x, y) in arow.iter().zip(brow.iter()) {
                    s += x * y;
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    /// Gram matrix `self^T * self` (`[R,R]`, used by analysis/FMS).
    pub fn gram(&self) -> Mat {
        let r = self.cols;
        let mut g = Mat::zeros(r, r);
        for i in 0..self.rows {
            let row = self.row(i);
            for a in 0..r {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                for b in 0..r {
                    *g.at_mut(a, b) += ra * row[b];
                }
            }
        }
        g
    }

    /// Per-column Euclidean norms.
    pub fn col_norms(&self) -> Vec<f64> {
        let mut out = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for (j, &v) in self.row(i).iter().enumerate() {
                out[j] += (v as f64) * (v as f64);
            }
        }
        out.iter_mut().for_each(|x| *x = x.sqrt());
        out
    }

    /// Extract column j.
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: &[f32]) -> Mat {
        Mat::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn matmul_small() {
        let a = m(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = m(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_transb_agrees_with_matmul() {
        let mut rng = Rng::new(1);
        let a = Mat::rand_normal(7, 5, 1.0, &mut rng);
        let b = Mat::rand_normal(6, 5, 1.0, &mut rng);
        let bt = Mat::from_fn(5, 6, |i, j| b.at(j, i));
        let c1 = a.matmul_transb(&b);
        let c2 = a.matmul(&bt);
        for (x, y) in c1.data.iter().zip(c2.data.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn axpy_scale_sub() {
        let mut a = m(2, 2, &[1., 2., 3., 4.]);
        let b = m(2, 2, &[1., 1., 1., 1.]);
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![3., 4., 5., 6.]);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.5, 2., 2.5, 3.]);
        a.sub_assign(&b);
        assert_eq!(a.data, vec![0.5, 1., 1.5, 2.]);
    }

    #[test]
    fn norms() {
        let a = m(1, 4, &[3., -4., 0., 0.]);
        assert!((a.frob() - 5.0).abs() < 1e-9);
        assert!((a.l1() - 7.0).abs() < 1e-9);
        let b = m(1, 4, &[0., 0., 0., 0.]);
        assert!((a.dist_sq(&b) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn gram_and_col_norms() {
        let a = m(3, 2, &[1., 0., 0., 2., 2., 0.]);
        let g = a.gram();
        assert_eq!(g.data, vec![5., 0., 0., 4.]);
        let n = a.col_norms();
        assert!((n[0] - 5.0f64.sqrt()).abs() < 1e-9);
        assert!((n[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn hadamard() {
        let mut a = m(2, 2, &[1., 2., 3., 4.]);
        a.hadamard_assign(&m(2, 2, &[2., 0.5, 1., 0.]));
        assert_eq!(a.data, vec![2., 1., 3., 0.]);
    }

    #[test]
    #[should_panic]
    fn matmul_dim_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
