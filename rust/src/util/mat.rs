//! Dense row-major f32 matrix substrate (no ndarray/BLAS offline).
//!
//! Factor matrices are tall-skinny (`I x R`, R <= 64), so the kernels here
//! are written for that regime: row-major layout, ikj GEMM loops that
//! vectorize well, and allocation-free `*_into` variants for the engine's
//! hot paths.
//!
//! The compute core runs on the slice-level kernels at the bottom of this
//! module ([`gemm_transb_into`], [`gemm_acc_into`], [`hadamard2_into`]):
//! they take raw `&[f32]` panels so the native backend can tile the
//! gradient over row blocks without materializing sub-matrices. Two
//! properties the engine relies on:
//!
//! * **Lane-deterministic reductions** — every dot product accumulates in
//!   a fixed `LANES`-wide register layout reduced in a fixed tree order
//!   (see `util/simd.rs`, which owns the lane kernels and their runtime
//!   SSE2/AVX2 dispatch), so results are bit-identical regardless of how
//!   callers tile or thread the row dimension — and regardless of the
//!   SIMD level the dispatcher picks.
//! * **Allocation freedom** — all `*_into` kernels write into
//!   caller-owned buffers; nothing here touches the heap.

use crate::util::rng::Rng;
use crate::util::simd::{self, Level};

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` over raw row-major slices, 2x2
/// register-tiled. This is the `M = A·Hᵀ` panel kernel of the gradient.
/// Dispatches to the process-wide [`simd::level`]; every level is
/// bit-identical (see `util/simd.rs`).
pub fn gemm_transb_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    gemm_transb_into_l(simd::level(), a, b, c, m, n, k);
}

/// [`gemm_transb_into`] at a forced SIMD level (tests sweep levels; the
/// backend resolves the level once and reuses it).
pub fn gemm_transb_into_l(
    lv: Level,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), n * k, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    let mut i = 0;
    while i + 2 <= m {
        let a0 = &a[i * k..(i + 1) * k];
        let a1 = &a[(i + 1) * k..(i + 2) * k];
        let mut j = 0;
        while j + 2 <= n {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let t = simd::dot2x2(lv, a0, a1, b0, b1, k);
            c[i * n + j] = t[0];
            c[i * n + j + 1] = t[1];
            c[(i + 1) * n + j] = t[2];
            c[(i + 1) * n + j + 1] = t[3];
            j += 2;
        }
        if j < n {
            let b0 = &b[j * k..(j + 1) * k];
            c[i * n + j] = simd::dot(lv, a0, b0);
            c[(i + 1) * n + j] = simd::dot(lv, a1, b0);
        }
        i += 2;
    }
    if i < m {
        let a0 = &a[i * k..(i + 1) * k];
        for j in 0..n {
            c[i * n + j] = simd::dot(lv, a0, &b[j * k..(j + 1) * k]);
        }
    }
}

/// `C[m,n] += A[m,k] · B[k,n]` over raw row-major slices, ikj order with
/// an elementwise axpy inner loop. This is the `G += Y·H` panel kernel of
/// the gradient; the zero-skip pays off because `Y = ∂f` is sparse
/// wherever the loss saturates.
pub fn gemm_acc_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    gemm_acc_into_l(simd::level(), a, b, c, m, n, k);
}

/// [`gemm_acc_into`] at a forced SIMD level.
pub fn gemm_acc_into_l(
    lv: Level,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            simd::axpy(lv, av, brow, crow);
        }
    }
}

/// Fused two-operand Hadamard: `out[e] = x[e] * y[e]` in one pass (the
/// common D=3 case writes `H = U₁ ⊙ U₂` without an intermediate copy).
pub fn hadamard2_into(x: &[f32], y: &[f32], out: &mut [f32]) {
    hadamard2_into_l(simd::level(), x, y, out);
}

/// [`hadamard2_into`] at a forced SIMD level.
pub fn hadamard2_into_l(lv: Level, x: &[f32], y: &[f32], out: &mut [f32]) {
    simd::hadamard2(lv, x, y, out);
}

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Encode the entries as a hex string of their IEEE-754 bit patterns
    /// (8 lowercase hex digits per `f32`, row-major). Used by the
    /// checkpoint layer: unlike decimal formatting this is exact for
    /// *every* value, including NaN payloads and signed zeros, so a
    /// decode round-trip is bit-identical by construction.
    pub fn encode_bits(&self) -> String {
        let mut s = String::with_capacity(self.data.len() * 8);
        for &v in &self.data {
            use std::fmt::Write;
            let _ = write!(s, "{:08x}", v.to_bits());
        }
        s
    }

    /// Inverse of [`Mat::encode_bits`].
    pub fn decode_bits(rows: usize, cols: usize, s: &str) -> anyhow::Result<Self> {
        let n = rows * cols;
        anyhow::ensure!(
            s.len() == n * 8,
            "matrix bit string has {} hex digits, expected {} for {rows}x{cols}",
            s.len(),
            n * 8
        );
        let b = s.as_bytes();
        let mut data = Vec::with_capacity(n);
        for i in 0..n {
            let chunk = std::str::from_utf8(&b[i * 8..i * 8 + 8])
                .map_err(|_| anyhow::anyhow!("non-ascii matrix bit string"))?;
            let bits = u32::from_str_radix(chunk, 16)
                .map_err(|_| anyhow::anyhow!("bad hex in matrix bit string: '{chunk}'"))?;
            data.push(f32::from_bits(bits));
        }
        Ok(Mat { rows, cols, data })
    }

    /// i.i.d. uniform entries in `[0, scale)` — the standard non-negative
    /// init for EHR tensor factorization.
    pub fn rand_uniform(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.uniform_f32() * scale);
        }
        Mat { rows, cols, data }
    }

    pub fn rand_normal(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.normal_f32() * std);
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// `self += alpha * other` (the engine's most-executed loop).
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        simd::axpy(simd::level(), alpha, &other.data, &mut self.data);
    }

    /// `self = alpha * self`.
    pub fn scale(&mut self, alpha: f32) {
        self.data.iter_mut().for_each(|x| *x *= alpha);
    }

    /// `self -= other`.
    pub fn sub_assign(&mut self, other: &Mat) {
        self.axpy(-1.0, other);
    }

    /// `self += other`.
    pub fn add_assign(&mut self, other: &Mat) {
        self.axpy(1.0, other);
    }

    /// Elementwise product accumulate: `self *= other`.
    pub fn hadamard_assign(&mut self, other: &Mat) {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        simd::hadamard_assign(simd::level(), &other.data, &mut self.data);
    }

    /// Squared Frobenius norm.
    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn frob(&self) -> f64 {
        self.frob_sq().sqrt()
    }

    /// l1 norm of all entries (sign-compressor scale).
    pub fn l1(&self) -> f64 {
        self.data.iter().map(|&x| x.abs() as f64).sum()
    }

    /// Squared Frobenius norm of `self - other` without allocating.
    pub fn dist_sq(&self, other: &Mat) -> f64 {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum()
    }

    /// `C = self * other` (`[m,k] x [k,n]`), ikj loop order.
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut c = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut c);
        c
    }

    pub fn matmul_into(&self, other: &Mat, c: &mut Mat) {
        assert_eq!(self.cols, other.rows);
        assert_eq!((c.rows, c.cols), (self.rows, other.cols));
        c.fill(0.0);
        gemm_acc_into(&self.data, &other.data, &mut c.data, self.rows, other.cols, self.cols);
    }

    /// `C += self * other` without zeroing `C` first.
    pub fn matmul_acc_into(&self, other: &Mat, c: &mut Mat) {
        assert_eq!(self.cols, other.rows);
        assert_eq!((c.rows, c.cols), (self.rows, other.cols));
        gemm_acc_into(&self.data, &other.data, &mut c.data, self.rows, other.cols, self.cols);
    }

    /// `C = self * other^T` (`[m,k] x [n,k]^T`), row-dot-row — cache friendly.
    pub fn matmul_transb(&self, other: &Mat) -> Mat {
        let mut c = Mat::zeros(self.rows, other.rows);
        self.matmul_transb_into(other, &mut c);
        c
    }

    /// `C = self * other^T` into a caller-owned buffer (2x2 register-tiled
    /// blocked kernel, no allocation).
    pub fn matmul_transb_into(&self, other: &Mat, c: &mut Mat) {
        assert_eq!(self.cols, other.cols);
        assert_eq!((c.rows, c.cols), (self.rows, other.rows));
        gemm_transb_into(&self.data, &other.data, &mut c.data, self.rows, other.rows, self.cols);
    }

    /// Gram matrix `self^T * self` (`[R,R]`, used by analysis/FMS).
    pub fn gram(&self) -> Mat {
        let r = self.cols;
        let mut g = Mat::zeros(r, r);
        for i in 0..self.rows {
            let row = self.row(i);
            for a in 0..r {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                for b in 0..r {
                    *g.at_mut(a, b) += ra * row[b];
                }
            }
        }
        g
    }

    /// Per-column Euclidean norms.
    pub fn col_norms(&self) -> Vec<f64> {
        let mut out = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for (j, &v) in self.row(i).iter().enumerate() {
                out[j] += (v as f64) * (v as f64);
            }
        }
        out.iter_mut().for_each(|x| *x = x.sqrt());
        out
    }

    /// Extract column j.
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: &[f32]) -> Mat {
        Mat::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn matmul_small() {
        let a = m(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = m(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_transb_agrees_with_matmul() {
        let mut rng = Rng::new(1);
        let a = Mat::rand_normal(7, 5, 1.0, &mut rng);
        let b = Mat::rand_normal(6, 5, 1.0, &mut rng);
        let bt = Mat::from_fn(5, 6, |i, j| b.at(j, i));
        let c1 = a.matmul_transb(&b);
        let c2 = a.matmul(&bt);
        for (x, y) in c1.data.iter().zip(c2.data.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn axpy_scale_sub() {
        let mut a = m(2, 2, &[1., 2., 3., 4.]);
        let b = m(2, 2, &[1., 1., 1., 1.]);
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![3., 4., 5., 6.]);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.5, 2., 2.5, 3.]);
        a.sub_assign(&b);
        assert_eq!(a.data, vec![0.5, 1., 1.5, 2.]);
    }

    #[test]
    fn norms() {
        let a = m(1, 4, &[3., -4., 0., 0.]);
        assert!((a.frob() - 5.0).abs() < 1e-9);
        assert!((a.l1() - 7.0).abs() < 1e-9);
        let b = m(1, 4, &[0., 0., 0., 0.]);
        assert!((a.dist_sq(&b) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn gram_and_col_norms() {
        let a = m(3, 2, &[1., 0., 0., 2., 2., 0.]);
        let g = a.gram();
        assert_eq!(g.data, vec![5., 0., 0., 4.]);
        let n = a.col_norms();
        assert!((n[0] - 5.0f64.sqrt()).abs() < 1e-9);
        assert!((n[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn hadamard() {
        let mut a = m(2, 2, &[1., 2., 3., 4.]);
        a.hadamard_assign(&m(2, 2, &[2., 0.5, 1., 0.]));
        assert_eq!(a.data, vec![2., 1., 3., 0.]);
    }

    #[test]
    #[should_panic]
    fn matmul_dim_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    /// Straight-line scalar reference for the blocked kernels.
    fn matmul_transb_naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.rows);
        for i in 0..a.rows {
            for j in 0..b.rows {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += (a.at(i, k) as f64) * (b.at(j, k) as f64);
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    #[test]
    fn blocked_transb_matches_naive_all_shapes() {
        let mut rng = Rng::new(31);
        // odd/even edges for both the 2x2 tile and the LANES tail
        for (m, n, k) in [(1, 1, 1), (2, 2, 8), (3, 5, 7), (8, 9, 16), (13, 6, 33), (5, 1, 12)] {
            let a = Mat::rand_normal(m, k, 1.0, &mut rng);
            let b = Mat::rand_normal(n, k, 1.0, &mut rng);
            let c = a.matmul_transb(&b);
            let want = matmul_transb_naive(&a, &b);
            for (x, y) in c.data.iter().zip(want.data.iter()) {
                assert!((x - y).abs() < 1e-4, "({m},{n},{k}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn blocked_transb_cells_are_tiling_invariant() {
        // a cell's value must not depend on whether the 2x2 tile or the
        // edge loop produced it: computing rows one at a time must agree
        // bitwise with the full blocked call
        let mut rng = Rng::new(32);
        let (m, n, k) = (7, 9, 20);
        let a = Mat::rand_normal(m, k, 1.0, &mut rng);
        let b = Mat::rand_normal(n, k, 1.0, &mut rng);
        let full = a.matmul_transb(&b);
        for i in 0..m {
            let arow = Mat::from_vec(1, k, a.row(i).to_vec());
            let single = arow.matmul_transb(&b);
            assert_eq!(single.data, full.data[i * n..(i + 1) * n].to_vec(), "row {i}");
        }
    }

    #[test]
    fn gemm_acc_accumulates() {
        let a = m(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = m(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let mut c = Mat::from_vec(2, 2, vec![1.0; 4]);
        a.matmul_acc_into(&b, &mut c);
        assert_eq!(c.data, vec![59., 65., 140., 155.]);
    }

    #[test]
    fn gemm_kernels_bit_identical_across_simd_levels() {
        // the dispatcher may pick SSE2 or AVX2 at runtime; whatever it
        // picks must match the scalar reference bitwise, for shapes
        // covering the 2x2 tile edges and every remainder-lane count
        let mut rng = Rng::new(41);
        for (m, n, k) in
            [(1, 1, 1), (2, 2, 8), (3, 5, 7), (8, 9, 16), (13, 6, 33), (5, 1, 12), (4, 4, 65)]
        {
            let a = Mat::rand_normal(m, k, 1.0, &mut rng);
            let b = Mat::rand_normal(n, k, 1.0, &mut rng);
            let mut want = vec![0.0f32; m * n];
            gemm_transb_into_l(Level::Scalar, &a.data, &b.data, &mut want, m, n, k);
            let bk = Mat::rand_normal(k, n, 1.0, &mut rng);
            let mut want_acc = vec![0.5f32; m * n];
            gemm_acc_into_l(Level::Scalar, &a.data, &bk.data, &mut want_acc, m, n, k);
            for lv in simd::available_levels() {
                let mut got = vec![0.0f32; m * n];
                gemm_transb_into_l(lv, &a.data, &b.data, &mut got, m, n, k);
                let same = got.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same, "transb ({m},{n},{k}) level={}", lv.name());
                let mut got_acc = vec![0.5f32; m * n];
                gemm_acc_into_l(lv, &a.data, &bk.data, &mut got_acc, m, n, k);
                let same = got_acc.iter().zip(&want_acc).all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same, "acc ({m},{n},{k}) level={}", lv.name());
            }
        }
    }

    #[test]
    fn hadamard2_matches_assign() {
        let mut rng = Rng::new(33);
        let x = Mat::rand_normal(5, 7, 1.0, &mut rng);
        let y = Mat::rand_normal(5, 7, 1.0, &mut rng);
        let mut out = vec![0.0f32; 35];
        hadamard2_into(&x.data, &y.data, &mut out);
        let mut want = x.clone();
        want.hadamard_assign(&y);
        assert_eq!(out, want.data);
    }
}
