//! Debug/test-gated runtime invariants for the determinism-critical hot
//! paths (session round loop, gossip consensus, sweep aggregation).
//!
//! Every check here guards a property the acceptance suite depends on
//! but that no single unit test can watch continuously:
//!
//! * **estimate-slot alignment** — a client's peer-estimate slots always
//!   mirror `sorted({neighbors} ∪ {self})` from the topology, so
//!   `slot_of` can never read another peer's estimate;
//! * **wire-byte conservation** — the bytes the `CommLedger`s grow by in
//!   one gossip round equal exactly the bytes charged at publish time
//!   (payload + header, once per neighbor), so `NetStats`/`CommBytes`
//!   reporting can never drift from what "traveled";
//! * **consensus finiteness** — the consensus fold introduces no
//!   non-finite values that were not already present in its inputs
//!   (a diverged run may legitimately carry NaN, but consensus itself
//!   must never manufacture one from finite inputs);
//! * **aggregator column-order fixity** — robust aggregators consume the
//!   neighbor list in the graph's strictly-increasing order, the premise
//!   behind their "[self, neighbors]" fixed value layout;
//! * **sweep expansion order** — the aggregate is written strictly in
//!   expansion-index order, never completion order.
//!
//! All functions compile to nothing in release builds: the bodies branch
//! on `cfg!(debug_assertions)` (a compile-time constant the optimizer
//! removes), so the hot paths pay zero cost outside tests and debug
//! binaries. The static side of this firewall is `cargo xtask verify`
//! (see `xtask/src/lint.rs`); ARCHITECTURE.md "Static analysis &
//! invariants" documents both halves.

/// Whether the invariant layer is active in this build (debug/test only).
/// Hot paths use this to skip the *preparation* of check inputs (byte
/// sums, finiteness scans) in release, not just the checks themselves.
pub const fn enabled() -> bool {
    cfg!(debug_assertions)
}

/// A client's estimate slots must be exactly
/// `sorted(dedup({neighbors} ∪ {client}))`: strictly increasing, self and
/// every neighbor present, nothing else. Asserted when clients are built
/// (session) and when [`crate::gossip::EstimateState`] is constructed.
pub fn estimate_slots_aligned(client: usize, peers: &[usize], neighbors: &[usize]) {
    if cfg!(debug_assertions) {
        assert!(
            peers.windows(2).all(|w| w[0] < w[1]),
            "invariant: client {client} estimate slots not strictly increasing: {peers:?}"
        );
        assert!(
            peers.contains(&client),
            "invariant: client {client} missing from its own estimate slots {peers:?}"
        );
        for n in neighbors {
            assert!(
                peers.contains(n),
                "invariant: client {client} has no estimate slot for neighbor {n} \
                 (slots {peers:?}, topology neighbors {neighbors:?})"
            );
        }
        for p in peers {
            assert!(
                *p == client || neighbors.contains(p),
                "invariant: client {client} tracks estimate slot {p} that is neither \
                 itself nor a topology neighbor {neighbors:?}"
            );
        }
    }
}

/// The robust aggregators collect values as `[self, neighbors...]` and
/// rely on the graph handing them neighbors in strictly-increasing order
/// (what [`crate::topology::Graph::build`] guarantees). A permuted list
/// would still be *correct* for permutation-invariant centers, but would
/// silently void the fixed-column-order contract the tests byte-compare
/// against — so it is asserted, not assumed.
pub fn neighbors_sorted(neighbors: &[usize]) {
    if cfg!(debug_assertions) {
        assert!(
            neighbors.windows(2).all(|w| w[0] < w[1]),
            "invariant: aggregator neighbor order not strictly increasing: {neighbors:?}"
        );
    }
}

/// Ledger bytes after one gossip round must have grown by exactly the
/// bytes charged at publish time: `(payload + header) × |neighbors|` per
/// fired client, with corruption/drops/latency all unable to change the
/// total (a Byzantine client lies about *content*, not byte counts).
pub fn wire_bytes_conserved(t: usize, before: u64, after: u64, expected: u64) {
    if cfg!(debug_assertions) {
        assert!(
            after - before == expected,
            "invariant: round {t} ledger bytes grew by {} but publish charged {expected} \
             (before {before}, after {after})",
            after - before
        );
    }
}

/// The consensus fold on one client/mode must not manufacture non-finite
/// values: if every input (the client's own factor plus all tracked peer
/// estimates for the mode) was finite, the folded factor must be too.
/// `inputs_finite` is computed by the caller *before* the fold (skip the
/// scan entirely when [`enabled`] is false).
pub fn consensus_kept_finite(client: usize, mode: usize, inputs_finite: bool, out: &[f32]) {
    if cfg!(debug_assertions) && inputs_finite {
        assert!(
            out.iter().all(|v| v.is_finite()),
            "invariant: consensus on client {client} mode {mode} produced a non-finite \
             value from all-finite inputs"
        );
    }
}

/// The sweep aggregate is written in expansion order: result `i` must
/// carry expansion index `i`, whatever order the worker pool finished in.
pub fn aggregate_expansion_order<I: IntoIterator<Item = usize>>(indices: I) {
    if cfg!(debug_assertions) {
        for (want, got) in indices.into_iter().enumerate() {
            assert!(
                want == got,
                "invariant: sweep aggregate slot {want} carries expansion index {got} \
                 — results permuted out of expansion order"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_slots_pass() {
        estimate_slots_aligned(1, &[0, 1, 2], &[0, 2]);
        // self-loop topologies list the client among its own neighbors
        estimate_slots_aligned(1, &[0, 1, 2], &[0, 1, 2]);
        neighbors_sorted(&[0, 2, 5]);
        neighbors_sorted(&[]);
        wire_bytes_conserved(0, 100, 164, 64);
        consensus_kept_finite(0, 1, true, &[1.0, -2.0]);
        // poisoned inputs exempt the output
        consensus_kept_finite(0, 1, false, &[f32::NAN]);
        aggregate_expansion_order([0usize, 1, 2]);
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "invariants compile out in release")]
    #[should_panic(expected = "not strictly increasing")]
    fn unsorted_slots_panic() {
        estimate_slots_aligned(1, &[2, 0, 1], &[0, 2]);
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "invariants compile out in release")]
    #[should_panic(expected = "no estimate slot for neighbor")]
    fn missing_neighbor_slot_panics() {
        estimate_slots_aligned(1, &[0, 1], &[0, 2]);
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "invariants compile out in release")]
    #[should_panic(expected = "publish charged")]
    fn unconserved_bytes_panic() {
        wire_bytes_conserved(3, 0, 10, 12);
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "invariants compile out in release")]
    #[should_panic(expected = "non-finite")]
    fn manufactured_nan_panics() {
        consensus_kept_finite(0, 1, true, &[1.0, f32::NAN]);
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "invariants compile out in release")]
    #[should_panic(expected = "expansion order")]
    fn permuted_aggregate_panics() {
        aggregate_expansion_order([1usize, 0]);
    }
}
