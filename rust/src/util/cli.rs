//! Tiny CLI argument parser substrate (no `clap` available offline).
//!
//! Model: `prog <subcommand> [--key value]... [--flag]...`. Typed getters
//! with defaults that **error** (never panic, never silently default) on
//! malformed or valueless options; unknown-argument detection with
//! did-you-mean hints via [`Args::finish`].

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    /// subcommand (first non-flag argument), if any
    pub command: Option<String>,
    /// non-flag arguments after the subcommand (e.g. `fleet spawn`'s
    /// `spawn`), in order
    positionals: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
    used_positionals: std::cell::Cell<usize>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut command = None;
        let mut positionals = Vec::new();
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    flags.push(key.to_string());
                }
            } else if command.is_none() {
                command = Some(a);
            } else {
                positionals.push(a);
            }
        }
        Args {
            command,
            positionals,
            opts,
            flags,
            consumed: Default::default(),
            used_positionals: Default::default(),
        }
    }

    /// The `idx`-th positional argument after the subcommand (e.g. the
    /// `spawn` in `fleet spawn --config f.json` is positional 0).
    /// Consulting index `idx` marks positionals `0..=idx` as expected,
    /// so [`Args::finish`] only rejects the genuinely unconsumed tail.
    pub fn positional(&self, idx: usize) -> Option<&str> {
        if idx + 1 > self.used_positionals.get() {
            self.used_positionals.set(idx + 1);
        }
        self.positionals.get(idx).map(String::as_str)
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// Error when `--key` was passed bare (no value) but a value is
    /// required — the old behaviour silently fell back to the default,
    /// so `--tau` followed by another flag quietly trained with τ = 4.
    fn reject_bare_flag(&self, key: &str) -> anyhow::Result<()> {
        if self.flags.iter().any(|f| f == key) {
            anyhow::bail!("--{key} expects a value, but none was given");
        }
        Ok(())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_str(&self, key: &str, default: &str) -> anyhow::Result<String> {
        self.mark(key);
        self.reject_bare_flag(key)?;
        Ok(self.opts.get(key).cloned().unwrap_or_else(|| default.to_string()))
    }

    pub fn opt_str(&self, key: &str) -> anyhow::Result<Option<String>> {
        self.mark(key);
        self.reject_bare_flag(key)?;
        Ok(self.opts.get(key).cloned())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        self.mark(key);
        self.reject_bare_flag(key)?;
        match self.opts.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        self.mark(key);
        self.reject_bare_flag(key)?;
        match self.opts.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'"))
            }
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        self.mark(key);
        self.reject_bare_flag(key)?;
        match self.opts.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    /// Comma-separated list of integers, e.g. `--taus 2,4,6,8`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
        self.mark(key);
        self.reject_bare_flag(key)?;
        match self.opts.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{key}: bad integer '{}'", s.trim()))
                })
                .collect(),
        }
    }

    /// Comma-separated list of strings.
    pub fn get_str_list(&self, key: &str, default: &[&str]) -> anyhow::Result<Vec<String>> {
        self.mark(key);
        self.reject_bare_flag(key)?;
        Ok(match self.opts.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').filter(|s| !s.is_empty()).map(|s| s.trim().to_string()).collect(),
        })
    }

    /// Error on any option/flag that was never queried (catches typos),
    /// with a did-you-mean hint against the flags this command actually
    /// consulted.
    pub fn finish(&self) -> anyhow::Result<()> {
        if self.positionals.len() > self.used_positionals.get() {
            anyhow::bail!(
                "unexpected argument{}: {}",
                if self.positionals.len() - self.used_positionals.get() == 1 { "" } else { "s" },
                self.positionals[self.used_positionals.get()..].join(", ")
            );
        }
        let seen = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !seen.contains(k))
            .collect();
        if unknown.is_empty() {
            return Ok(());
        }
        let mut msgs: Vec<String> = Vec::with_capacity(unknown.len());
        for u in &unknown {
            match crate::registry::did_you_mean(u, seen.iter().map(String::as_str)) {
                Some(s) => msgs.push(format!("--{u} (did you mean --{s}?)")),
                None => msgs.push(format!("--{u}")),
            }
        }
        let mut known: Vec<&str> = seen.iter().map(String::as_str).collect();
        known.sort_unstable();
        known.dedup();
        anyhow::bail!(
            "unknown argument{}: {}\nthis command accepts: {}",
            if msgs.len() == 1 { "" } else { "s" },
            msgs.join(", "),
            known.iter().map(|k| format!("--{k}")).collect::<Vec<_>>().join(" ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig3 --dataset mimic_like --workers 16 --lr 0.125 --verbose");
        assert_eq!(a.command.as_deref(), Some("fig3"));
        assert_eq!(a.get_str("dataset", "synthetic").unwrap(), "mimic_like");
        assert_eq!(a.get_usize("workers", 8).unwrap(), 16);
        assert!((a.get_f64("lr", 1.0).unwrap() - 0.125).abs() < 1e-12);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn equals_syntax_and_lists() {
        let a = parse("train --taus=2,4,6,8 --algos cidertf,dpsgd");
        assert_eq!(a.get_usize_list("taus", &[1]).unwrap(), vec![2, 4, 6, 8]);
        assert_eq!(a.get_str_list("algos", &[]).unwrap(), vec!["cidertf", "dpsgd"]);
        assert!(parse("train --taus 2,x,8").get_usize_list("taus", &[1]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_usize("k", 8).unwrap(), 8);
        assert_eq!(a.get_str("loss", "logit").unwrap(), "logit");
        assert_eq!(a.opt_str("out").unwrap(), None);
    }

    #[test]
    fn unknown_args_detected_with_suggestion() {
        let a = parse("run --epoch 3");
        a.get_usize("epochs", 8).unwrap();
        let err = format!("{:#}", a.finish().unwrap_err());
        assert!(err.contains("--epoch"), "{err}");
        assert!(err.contains("did you mean --epochs?"), "{err}");
    }

    #[test]
    fn unknown_args_without_suggestion_list_known() {
        let a = parse("run --zzqq 3");
        a.get_usize("k", 8).unwrap();
        let err = format!("{:#}", a.finish().unwrap_err());
        assert!(err.contains("--zzqq"), "{err}");
        assert!(err.contains("--k"), "{err}");
    }

    #[test]
    fn positionals_after_the_subcommand() {
        let a = parse("fleet spawn --config fleet.json");
        assert_eq!(a.command.as_deref(), Some("fleet"));
        assert_eq!(a.positional(0), Some("spawn"));
        assert_eq!(a.positional(1), None);
        a.get_str("config", "").unwrap();
        assert!(a.finish().is_ok());

        // an unconsumed positional is an error, not silently dropped
        let a = parse("train extra");
        let err = format!("{:#}", a.finish().unwrap_err());
        assert!(err.contains("unexpected argument") && err.contains("extra"), "{err}");
    }

    #[test]
    fn type_errors_are_errors_not_panics() {
        let a = parse("run --k abc");
        let err = format!("{:#}", a.get_usize("k", 8).unwrap_err());
        assert!(err.contains("--k") && err.contains("abc"), "{err}");
        let a = parse("run --gamma 1.5.2");
        assert!(a.get_f64("gamma", 1.0).is_err());
    }

    #[test]
    fn bare_flag_where_value_expected_is_an_error() {
        // `--tau --epochs 5` used to silently train with the default tau
        let a = parse("train --tau --epochs 5");
        let err = format!("{:#}", a.get_usize("tau", 4).unwrap_err());
        assert!(err.contains("--tau") && err.contains("expects a value"), "{err}");
        assert_eq!(a.get_usize("epochs", 1).unwrap(), 5);
    }
}
