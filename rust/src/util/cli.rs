//! Tiny CLI argument parser substrate (no `clap` available offline).
//!
//! Model: `prog <subcommand> [--key value]... [--flag]...`. Typed getters
//! with defaults; unknown-argument detection via `finish()`.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    /// subcommand (first non-flag argument), if any
    pub command: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut command = None;
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    flags.push(key.to_string());
                }
            } else if command.is_none() {
                command = Some(a);
            }
        }
        Args { command, opts, flags, consumed: Default::default() }
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.opts.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.opts.get(key).cloned()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.mark(key);
        self.opts
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.mark(key);
        self.opts
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.mark(key);
        self.opts
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list of integers, e.g. `--taus 2,4,6,8`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        self.mark(key);
        match self.opts.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{key}: bad integer {s:?}")))
                .collect(),
        }
    }

    /// Comma-separated list of strings.
    pub fn get_str_list(&self, key: &str, default: &[&str]) -> Vec<String> {
        self.mark(key);
        match self.opts.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').filter(|s| !s.is_empty()).map(|s| s.trim().to_string()).collect(),
        }
    }

    /// Error on any option/flag that was never queried (catches typos).
    pub fn finish(&self) -> anyhow::Result<()> {
        let seen = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !seen.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unknown arguments: {unknown:?}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig3 --dataset mimic_like --workers 16 --lr 0.125 --verbose");
        assert_eq!(a.command.as_deref(), Some("fig3"));
        assert_eq!(a.get_str("dataset", "synthetic"), "mimic_like");
        assert_eq!(a.get_usize("workers", 8), 16);
        assert!((a.get_f64("lr", 1.0) - 0.125).abs() < 1e-12);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn equals_syntax_and_lists() {
        let a = parse("train --taus=2,4,6,8 --algos cidertf,dpsgd");
        assert_eq!(a.get_usize_list("taus", &[1]), vec![2, 4, 6, 8]);
        assert_eq!(a.get_str_list("algos", &[]), vec!["cidertf", "dpsgd"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_usize("k", 8), 8);
        assert_eq!(a.get_str("loss", "logit"), "logit");
        assert_eq!(a.opt_str("out"), None);
    }

    #[test]
    fn unknown_args_detected() {
        let a = parse("run --oops 3");
        a.get_usize("k", 8);
        assert!(a.finish().is_err());
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn type_error_panics() {
        let a = parse("run --k abc");
        a.get_usize("k", 8);
    }
}
