//! From-scratch substrates: PRNG, JSON, CLI, dense matrices, CSV, bench
//! harness, and property testing. See DESIGN.md "Environment constraints" —
//! none of the usual crates (rand/serde_json/clap/ndarray/criterion/
//! proptest) are available offline, so this crate carries its own.

pub mod benchkit;
pub mod cli;
pub mod csv;
pub mod invariant;
pub mod json;
pub mod mat;
pub mod order;
pub mod propcheck;
pub mod rng;
pub mod simd;
