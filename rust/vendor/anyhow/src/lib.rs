//! Minimal vendored subset of the `anyhow` error-handling API.
//!
//! The build environment has no network access to crates.io, so this crate
//! provides the (small) slice of `anyhow` the workspace actually uses:
//! [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros.
//! The design mirrors upstream `anyhow`: `Error` is an opaque wrapper around
//! a boxed [`std::error::Error`], deliberately does **not** implement
//! `std::error::Error` itself (so the blanket `From` impl below stays
//! coherent with `impl<T> From<T> for T`), and renders the source chain in
//! its `Debug` output.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` — the crate-wide fallible return type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque, dynamically-typed error.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Wrap any concrete error type.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error { inner: Box::new(error) }
    }

    /// Build an error from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { inner: Box::new(MessageError(message.to_string())) }
    }

    /// The chain's root-level message (identical to `Display`).
    pub fn to_string_chainless(&self) -> String {
        self.inner.to_string()
    }

    /// Borrow the wrapped error.
    pub fn as_dyn(&self) -> &(dyn StdError + Send + Sync + 'static) {
        self.inner.as_ref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(cause) = source {
            write!(f, "\n    {cause}")?;
            source = cause.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error { inner: Box::new(error) }
    }
}

/// A plain-string error (the payload behind `anyhow!`-built errors).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::format!(
                "condition failed: `{}`",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn needs_message() -> Result<()> {
        bail!("failed with code {}", 7)
    }

    fn needs_ensure(x: usize) -> Result<usize> {
        ensure!(x > 1);
        ensure!(x < 10, "x too large: {x}");
        Ok(x)
    }

    #[test]
    fn macros_produce_messages() {
        let e = needs_message().unwrap_err();
        assert_eq!(e.to_string(), "failed with code 7");
        assert!(needs_ensure(5).is_ok());
        assert!(needs_ensure(0).unwrap_err().to_string().contains("condition failed"));
        assert_eq!(needs_ensure(50).unwrap_err().to_string(), "x too large: 50");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        let e = parse("nope").unwrap_err();
        assert!(!e.to_string().is_empty());
        // Debug rendering never panics and includes the message.
        assert!(format!("{e:?}").contains(&e.to_string()));
    }
}
