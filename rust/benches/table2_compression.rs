//! Bench: Table II compression ratios — analytical matrix plus measured
//! per-payload wire sizes for every compressor.
use cidertf::compress::Compressor;
use cidertf::harness::tables;
use cidertf::util::benchkit::{bench, Table};
use cidertf::util::mat::Mat;
use cidertf::util::rng::Rng;

fn main() {
    tables::table2(3, 4);
    tables::table2(4, 8);

    println!("\nmeasured payload sizes (320x16 factor delta):");
    let mut rng = Rng::new(1);
    let m = Mat::rand_normal(320, 16, 1.0, &mut rng);
    let t = Table::new(&["compressor", "payload_bytes", "vs_dense"]);
    let dense = Compressor::None.compress(&m).wire_bytes();
    for c in [Compressor::None, Compressor::Sign, Compressor::TopK { ratio: 64 }] {
        let b = c.compress(&m).wire_bytes();
        t.row(&[c.name().to_string(), b.to_string(), format!("{:.4}", b as f64 / dense as f64)]);
    }

    println!("\ncompressor throughput:");
    bench("sign_compress_320x16", 300, || Compressor::Sign.compress(&m));
    let p = Compressor::Sign.compress(&m);
    let mut target = Mat::zeros(320, 16);
    bench("sign_decode_add_320x16", 300, || p.add_into(&mut target));
}
