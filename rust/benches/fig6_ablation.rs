//! Bench: regenerate paper Fig. 6 (communication-reduction ablation).
use cidertf::harness::{fig6, Ctx, Profile};

fn main() {
    let profile = Profile::from_name(
        &std::env::var("CIDERTF_PROFILE").unwrap_or_else(|_| "quick".into()),
    )
    .unwrap();
    let mut ctx = Ctx::new(profile).expect("artifacts missing — run `make artifacts`");
    fig6::run(&mut ctx, 8, 4).unwrap();
}
