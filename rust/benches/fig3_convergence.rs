//! Bench: regenerate paper Fig. 3 (convergence vs baselines).
//! `cargo bench --bench fig3_convergence` runs the quick profile;
//! CIDERTF_PROFILE=paper runs the paper settings.
use cidertf::harness::{fig3, Ctx, Profile};

fn main() {
    let profile = Profile::from_name(
        &std::env::var("CIDERTF_PROFILE").unwrap_or_else(|_| "quick".into()),
    )
    .unwrap();
    let mut ctx = Ctx::new(profile).expect("artifacts missing — run `make artifacts`");
    let taus = if profile == Profile::Paper { vec![2, 4, 6, 8] } else { vec![4, 8] };
    fig3::run(&mut ctx, 8, &taus).unwrap();
}
