//! Bench: regenerate paper Fig. 7 (FMS vs the centralized BrasCPD).
use cidertf::harness::{fig7, Ctx, Profile};

fn main() {
    let profile = Profile::from_name(
        &std::env::var("CIDERTF_PROFILE").unwrap_or_else(|_| "quick".into()),
    )
    .unwrap();
    let mut ctx = Ctx::new(profile).expect("artifacts missing — run `make artifacts`");
    fig7::run(&mut ctx, 8, 4).unwrap();
}
