//! Bench: regenerate paper Fig. 5 (scalability K = 8, 16, 32).
use cidertf::harness::{fig5, Ctx, Profile};

fn main() {
    let profile = Profile::from_name(
        &std::env::var("CIDERTF_PROFILE").unwrap_or_else(|_| "quick".into()),
    )
    .unwrap();
    let mut ctx = Ctx::new(profile).expect("artifacts missing — run `make artifacts`");
    let (ks, taus): (Vec<usize>, Vec<usize>) =
        if profile == Profile::Paper { (vec![8, 16, 32], vec![4, 8]) } else { (vec![8, 16, 32], vec![4]) };
    fig5::run(&mut ctx, &ks, &taus).unwrap();
}
