//! Micro-benchmarks of the L3 hot paths (the §Perf profiling harness):
//! fiber-slice gather, Khatri-Rao row gather, sign encode/decode,
//! consensus AXPY, and the full gradient step on both backends.

use cidertf::compress::Compressor;
use cidertf::engine::client::gather_rows;
use cidertf::factor::FactorSet;
use cidertf::losses::Loss;
use cidertf::runtime::native::NativeBackend;
use cidertf::runtime::{default_artifact_dir, ComputeBackend, PjrtBackend};
use cidertf::sched::FiberSampler;
use cidertf::tensor::fiber::FiberIndex;
use cidertf::tensor::partition::partition_mode0;
use cidertf::tensor::synth::SynthConfig;
use cidertf::util::benchkit::bench;
use cidertf::util::mat::Mat;
use cidertf::util::rng::Rng;

fn main() {
    // production-shaped client shard: mimic_like K=8 -> 544 x 320 x 320
    let data = SynthConfig::mimic_like().generate();
    let shard = partition_mode0(&data.tensor, 8).into_iter().next().unwrap();
    let dims = shard.tensor.dims.clone();
    let (s, r) = (256usize, 16usize);
    println!("shard {:?}, {} nnz; |S|={s}, R={r}\n", dims, shard.tensor.nnz());

    // --- hot path 1: sparse -> dense fiber slice gather ---
    let fi0 = FiberIndex::build(&shard.tensor, 0);
    let fi1 = FiberIndex::build(&shard.tensor, 1);
    let mut sampler = FiberSampler::new(7, 0);
    let n0 = shard.tensor.n_fibers(0);
    let n1 = shard.tensor.n_fibers(1);
    let mut xs0 = vec![0.0f32; dims[0] * s];
    let mut xs1 = vec![0.0f32; dims[1] * s];
    let fibers0 = sampler.sample(n0, s);
    let fibers1 = sampler.sample(n1, s);
    bench("gather_slice_patient_544xS", 400, || fi0.gather_slice(&fibers0, dims[0], &mut xs0));
    bench("gather_slice_feature_320xS", 400, || fi1.gather_slice(&fibers1, dims[1], &mut xs1));

    // --- hot path 2: Khatri-Rao row gather ---
    let factors = FactorSet::init_uniform(&dims, r, 0.3, 3);
    let mut u_bufs = vec![Mat::zeros(s, r), Mat::zeros(s, r)];
    bench("gather_krp_rows_mode0", 400, || {
        gather_rows(&factors, 0, &dims, &fibers0, &mut u_bufs)
    });

    // --- hot path 3: compression ---
    let mut rng = Rng::new(9);
    let delta = Mat::rand_normal(dims[1], r, 0.1, &mut rng);
    bench("sign_compress_320x16", 300, || Compressor::Sign.compress(&delta));
    let payload = Compressor::Sign.compress(&delta);
    let mut hat = Mat::zeros(dims[1], r);
    bench("sign_decode_add_320x16", 300, || payload.add_into(&mut hat));

    // --- hot path 4: consensus AXPY ---
    let a = Mat::rand_normal(dims[1], r, 0.1, &mut rng);
    let mut target = Mat::zeros(dims[1], r);
    bench("consensus_axpy_320x16", 300, || target.axpy(0.33, &a));

    // --- hot path 5: full gradient step, naive vs blocked vs PJRT ---
    let u_refs: Vec<&Mat> = u_bufs.iter().collect();
    let mut native = NativeBackend::new();
    bench("grad_native_naive_patient_544xS", 2000, || {
        native
            .grad_naive(Loss::Logit, &xs0, dims[0], s, &factors.mats[0], &u_refs, 1.0 / s as f32)
            .unwrap()
    });
    let mut g_out = Mat::zeros(dims[0], r);
    bench("grad_native_blocked_patient_544xS", 2000, || {
        native
            .grad_into(
                Loss::Logit,
                &xs0,
                dims[0],
                s,
                &factors.mats[0],
                &u_bufs,
                1.0 / s as f32,
                &mut g_out,
            )
            .unwrap()
    });
    let dir = default_artifact_dir();
    if dir.join("manifest.json").exists() {
        let mut pjrt = PjrtBackend::new(&dir).unwrap();
        bench("grad_pjrt_patient_544xS", 2000, || {
            pjrt.grad(Loss::Logit, &xs0, dims[0], s, &factors.mats[0], &u_refs, 1.0 / s as f32)
                .unwrap()
        });
        bench("grad_pjrt_feature_320xS", 2000, || {
            pjrt.grad(Loss::Logit, &xs1, dims[1], s, &factors.mats[1], &u_refs, 1.0 / s as f32)
                .unwrap()
        });
        // eval path (loss-estimator batch)
        let b = 8192;
        let mut ubufs: Vec<Mat> = Vec::new();
        for m in 0..3 {
            let mut buf = Mat::zeros(b, r);
            for row in 0..b {
                let i = row % factors.mats[m].rows;
                buf.row_mut(row).copy_from_slice(factors.mats[m].row(i));
            }
            ubufs.push(buf);
        }
        let urefs: Vec<&Mat> = ubufs.iter().collect();
        let x = vec![0.0f32; b];
        bench("eval_pjrt_8192x16", 2000, || pjrt.eval(Loss::Logit, &x, &urefs).unwrap());
    } else {
        println!("(PJRT benches skipped: run `make artifacts`)");
    }
}
