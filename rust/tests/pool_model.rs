//! Bounded exhaustive model checker for the worker pool's lock-free
//! claim protocol (`cidertf::runtime::pool::claim`).
//!
//! The production claim loop and this checker share one transition
//! function — [`step`] — so the protocol verified here is the protocol
//! that runs in `parallel_for`, not a hand-copied model of it. The
//! checker swaps the real atomics for a simulated shared memory
//! ([`Mem`]) and enumerates *every* interleaving of participant steps
//! (depth-first over the global state graph, deduplicated by a visited
//! set) for small configurations: 2–3 participants × 2–4 jobs × every
//! panic mask (a fixed subset of masks at 4 jobs).
//!
//! Checked properties, at every reachable terminal state:
//!
//! * every job runs exactly once — no lost or duplicated claims;
//! * `remaining` hits zero exactly — no underflow, nothing left over;
//! * a panicking job raises the task flag and publishes a payload from
//!   a genuinely panicking slot; panic-free runs publish nothing;
//! * the caller is woken exactly once, and only after `remaining == 0`;
//! * no reachable state deadlocks (some participant can always step
//!   until everyone is done).
//!
//! Honest scope note: participants interleave at `ClaimOps`-method
//! granularity, which matches the protocol's real atomicity (each
//! method is one atomic RMW or one mutex-serialized section). The
//! condvar handshake is modeled conservatively — the caller's wait is
//! simply not runnable until `remaining == 0` — so lost-wakeup bugs in
//! the condvar usage itself are out of scope here; the TSan CI lane
//! exercises that surface on the real threads instead.
//!
//! The checker is validated by two seeded mutants (a torn, non-atomic
//! claim and a dropped decrement on the panic path); both must be
//! caught or the harness itself is broken.

use std::cell::RefCell;
use std::collections::BTreeSet;

use cidertf::runtime::pool::claim::{step, ClaimOps, Pc};

/// Simulated shared memory of one posted task. Mirrors the fields of
/// the pool's `Task` plus sticky violation flags; every field is
/// bounded so the reachable state space is finite.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct Mem {
    /// Total jobs (immutable).
    n: usize,
    /// Claim cursor (`Task::next`). Capped at `n + NEXT_SLACK` so even
    /// buggy mutants keep the state space finite.
    next: usize,
    /// Unfinished-job count (`Task::remaining`).
    remaining: usize,
    /// Task-wide panic flag (`Task::panicked`).
    panicked: bool,
    /// Slot whose panic payload won the first-wins race, if any.
    payload: Option<usize>,
    /// Per-job run count, saturating at 2 (2 means "ran more than once"
    /// — the exact count past the violation does not matter).
    runs: Vec<u8>,
    /// Sticky: some participant ran a slot `>= n`.
    oob: bool,
    /// Sticky: `finish()` decremented past zero.
    underflow: bool,
    /// Caller wakeups delivered, saturating at 2.
    notifies: u8,
}

/// Headroom on the claim-cursor cap: enough for every participant's
/// drained-claim overshoot, with slack so capping never masks a real
/// protocol state.
const NEXT_SLACK: usize = 8;

impl Mem {
    fn new(jobs: usize) -> Self {
        Mem {
            n: jobs,
            next: 0,
            remaining: jobs,
            panicked: false,
            payload: None,
            runs: vec![0; jobs],
            oob: false,
            underflow: false,
            notifies: 0,
        }
    }
}

/// [`ClaimOps`] over the simulated memory. Each method is one atomic
/// action, exactly like its `TaskClaim` counterpart in the pool.
struct MemRef<'a> {
    mem: &'a RefCell<Mem>,
    /// Bit `j` set ⇒ job `j` panics when run.
    mask: u32,
}

impl ClaimOps for MemRef<'_> {
    fn claim(&self) -> usize {
        let mut m = self.mem.borrow_mut();
        let v = m.next;
        m.next = (v + 1).min(m.n + NEXT_SLACK);
        v
    }

    fn n(&self) -> usize {
        self.mem.borrow().n
    }

    fn run(&self, slot: usize) -> bool {
        let mut m = self.mem.borrow_mut();
        if slot >= m.n {
            m.oob = true;
            return false;
        }
        m.runs[slot] = (m.runs[slot] + 1).min(2);
        (self.mask >> slot) & 1 == 1
    }

    fn set_panicked(&self) {
        self.mem.borrow_mut().panicked = true;
    }

    fn offer_payload(&self, slot: usize) {
        let mut m = self.mem.borrow_mut();
        if m.payload.is_none() {
            m.payload = Some(slot);
        }
    }

    fn finish(&self) -> bool {
        let mut m = self.mem.borrow_mut();
        if m.remaining == 0 {
            m.underflow = true;
            return false;
        }
        m.remaining -= 1;
        m.remaining == 0
    }

    fn notify_done(&self) {
        let mut m = self.mem.borrow_mut();
        m.notifies = (m.notifies + 1).min(2);
    }
}

/// Program counter of one model thread. Thread 0 is the posting caller
/// (it participates in the claim loop, then waits for stragglers);
/// every other thread is a pool worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum TPc {
    /// Inside the shared claim loop at protocol position `pc`.
    Loop(Pc),
    /// Torn-claim mutant only: read `next == v`; the `v + 1` store is
    /// still pending, so another thread can claim the same slot.
    ClaimStore(usize),
    /// Caller parked on the done condvar; runnable once
    /// `remaining == 0`.
    CallerWait,
    /// Terminal.
    Done,
}

/// Seeded protocol bugs used to validate that the checker actually has
/// teeth. `None` is the real protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mutation {
    /// The real protocol, driven through the real [`step`] function.
    None,
    /// Splits the claim fetch-add into a racy read + store pair.
    NonAtomicClaim,
    /// Panicking jobs skip the `finish()` decrement.
    SkipPanicFinish,
}

/// `true` if `pc` may take a step given the current memory.
fn runnable(pc: &TPc, mem: &Mem) -> bool {
    match pc {
        TPc::Done => false,
        TPc::CallerWait => mem.remaining == 0,
        TPc::Loop(_) | TPc::ClaimStore(_) => true,
    }
}

/// Advance one thread by exactly one shared-memory step, returning its
/// next program counter and the successor memory.
fn step_thread(pc: &TPc, mem: &Mem, mask: u32, mutation: Mutation, is_caller: bool) -> (TPc, Mem) {
    // a participant leaving the claim loop exits; the caller then waits
    // for stragglers while workers are simply done with this task
    let exit = |caller: bool| if caller { TPc::CallerWait } else { TPc::Done };

    let cell = RefCell::new(mem.clone());
    let ops = MemRef { mem: &cell, mask };
    let npc = match pc {
        TPc::Loop(p) => match (mutation, *p) {
            (Mutation::NonAtomicClaim, Pc::Claim) => {
                // mutant: the read half of a torn claim (no increment)
                let v = cell.borrow().next;
                TPc::ClaimStore(v)
            }
            (Mutation::SkipPanicFinish, Pc::OfferPayload(slot)) => {
                // mutant: publish the payload but skip Finish entirely,
                // losing the `remaining` decrement for this job
                ops.offer_payload(slot);
                TPc::Loop(Pc::Claim)
            }
            _ => match step(*p, &ops) {
                Pc::Exit => exit(is_caller),
                next => TPc::Loop(next),
            },
        },
        TPc::ClaimStore(v) => {
            // mutant: the store half of the torn claim, then the same
            // drained-or-run branch the real protocol takes
            let (v, n) = (*v, cell.borrow().n);
            cell.borrow_mut().next = (v + 1).min(n + NEXT_SLACK);
            if v >= n {
                exit(is_caller)
            } else {
                TPc::Loop(Pc::Run(v))
            }
        }
        TPc::CallerWait => TPc::Done,
        TPc::Done => TPc::Done,
    };
    (npc, cell.into_inner())
}

/// Invariants that must hold when every thread is `Done`.
fn verify_terminal(mem: &Mem, jobs: usize, mask: u32) -> Result<(), String> {
    if mem.oob {
        return Err("a job slot >= n was run".into());
    }
    if mem.underflow {
        return Err("`remaining` underflowed".into());
    }
    if mem.remaining != 0 {
        return Err(format!("remaining = {} at termination", mem.remaining));
    }
    for (j, &r) in mem.runs.iter().enumerate() {
        if r != 1 {
            return Err(format!("job {j} ran {r} time(s), want exactly 1"));
        }
    }
    let should_panic = (mask & ((1u32 << jobs) - 1)) != 0;
    if mem.panicked != should_panic {
        return Err(format!("panicked flag = {} but panic mask = {mask:#b}", mem.panicked));
    }
    match mem.payload {
        Some(slot) if (mask >> slot) & 1 == 1 => {}
        Some(slot) => return Err(format!("payload from non-panicking job {slot}")),
        None if should_panic => return Err("panic payload lost".into()),
        None => {}
    }
    if mem.notifies != 1 {
        return Err(format!("caller woken {} time(s), want exactly 1", mem.notifies));
    }
    Ok(())
}

/// Exhaustively explore every interleaving of `threads` participants
/// (thread 0 is the caller) over `jobs` jobs where job `j` panics iff
/// bit `j` of `mask` is set. Returns the number of distinct global
/// states explored, or a description of the first violation found.
fn check(threads: usize, jobs: usize, mask: u32, mutation: Mutation) -> Result<u64, String> {
    let init = ((0..threads).map(|_| TPc::Loop(Pc::Claim)).collect::<Vec<_>>(), Mem::new(jobs));
    let mut visited: BTreeSet<(Vec<TPc>, Mem)> = BTreeSet::new();
    visited.insert(init.clone());
    let mut stack = vec![init];

    while let Some((pcs, mem)) = stack.pop() {
        if pcs.iter().all(|p| *p == TPc::Done) {
            verify_terminal(&mem, jobs, mask).map_err(|e| format!("{e} (mem: {mem:?})"))?;
            continue;
        }
        let mut any = false;
        for (t, pc) in pcs.iter().enumerate() {
            if !runnable(pc, &mem) {
                continue;
            }
            any = true;
            let (npc, nmem) = step_thread(pc, &mem, mask, mutation, t == 0);
            let mut npcs = pcs.clone();
            npcs[t] = npc;
            let succ = (npcs, nmem);
            if visited.insert(succ.clone()) {
                stack.push(succ);
            }
        }
        if !any {
            return Err(format!("deadlock: pcs = {pcs:?}, mem = {mem:?}"));
        }
    }
    Ok(visited.len() as u64)
}

/// The panic masks explored for a given job count: every mask up to
/// 3 jobs, and a representative subset (none, one, adjacent pair, all)
/// at 4 jobs to keep the largest configurations tractable.
fn masks_for(jobs: usize) -> Vec<u32> {
    if jobs <= 3 {
        (0..(1u32 << jobs)).collect()
    } else {
        vec![0b0000, 0b0001, 0b0110, 0b1111]
    }
}

#[test]
fn real_protocol_bounded_exhaustive() {
    for threads in [2usize, 3] {
        for jobs in [2usize, 3, 4] {
            for mask in masks_for(jobs) {
                let states = check(threads, jobs, mask, Mutation::None).unwrap_or_else(|e| {
                    panic!("threads={threads} jobs={jobs} mask={mask:#b}: {e}")
                });
                assert!(states > 0, "threads={threads} jobs={jobs}: explored nothing");
            }
        }
    }
}

#[test]
fn exploration_is_genuinely_exhaustive() {
    // loose floors on the distinct-state counts: if a refactor of the
    // checker accidentally serializes the schedule (e.g. always stepping
    // thread 0 first and never backtracking), these collapse to the
    // handful of states on one path and the floors fail
    let two_by_two = check(2, 2, 0, Mutation::None).unwrap();
    assert!(two_by_two >= 30, "2 threads x 2 jobs explored only {two_by_two} states");
    let three_by_three = check(3, 3, 0b111, Mutation::None).unwrap();
    assert!(three_by_three >= 300, "3 threads x 3 jobs explored only {three_by_three} states");
    // more threads must strictly widen the reachable interleavings
    let three_by_two = check(3, 2, 0, Mutation::None).unwrap();
    assert!(three_by_two > two_by_two, "adding a thread did not widen the state space");
}

#[test]
fn torn_claim_mutant_is_caught() {
    // splitting the claim fetch-add lets two threads claim one slot;
    // the checker must observe a duplicated/lost run or the resulting
    // remaining-count corruption in some interleaving
    let r = check(2, 2, 0, Mutation::NonAtomicClaim);
    let msg = r.expect_err("torn-claim mutant escaped the checker");
    assert!(
        msg.contains("ran") || msg.contains("underflow") || msg.contains("remaining"),
        "torn claim surfaced as an unexpected violation: {msg}"
    );
}

#[test]
fn lost_panic_decrement_mutant_is_caught() {
    // a panicking job that skips finish() leaves remaining > 0 forever:
    // every worker drains and exits, the caller waits on a count that
    // can never reach zero, and the checker reports the deadlock
    let r = check(2, 2, 0b01, Mutation::SkipPanicFinish);
    let msg = r.expect_err("lost-decrement mutant escaped the checker");
    assert!(msg.contains("deadlock"), "lost decrement surfaced unexpectedly: {msg}");
}

#[test]
fn mutants_pass_on_configs_that_cannot_expose_them() {
    // sanity check on the harness itself: SkipPanicFinish only differs
    // from the real protocol on the panic path, so a panic-free run
    // must still verify — the mutant tests above are meaningful only
    // if detection tracks the seeded bug, not the mutation flag
    check(2, 2, 0, Mutation::SkipPanicFinish)
        .expect("panic-free run must not distinguish SkipPanicFinish");
}
