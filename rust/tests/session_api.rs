//! Experiment-API integration tests: ExperimentSpec JSON round-trip
//! (property), Scenario error paths with did-you-mean hints, the
//! observer event stream, stopping rules, and — the acceptance
//! criterion — bit-identical checkpoint/resume under both the Ideal and
//! a Faulty network.

use std::path::PathBuf;

use cidertf::adversary::AdversarySchedule;
use cidertf::compress::Compressor;
use cidertf::engine::presets::Scenario;
use cidertf::gossip::Aggregator;
use cidertf::engine::session::{Observer, Session, SessionEvent};
use cidertf::engine::spec::{ExperimentSpec, StopRule};
use cidertf::engine::{train, AlgoConfig, TrainOutcome};
use cidertf::losses::Loss;
use cidertf::net::driver::DriverKind;
use cidertf::net::sim::FaultConfig;
use cidertf::registry;
use cidertf::runtime::native::NativeBackend;
use cidertf::tensor::partition::Partitioner;
use cidertf::tensor::synth::SynthData;
use cidertf::topology::Topology;
use cidertf::util::propcheck::forall;
use cidertf::util::rng::Rng;

// ---------------------------------------------------------------------
// spec JSON round-trip (property)
// ---------------------------------------------------------------------

fn gen_spec(rng: &mut Rng) -> ExperimentSpec {
    let algo_names = registry::algos().names();
    let name = algo_names[rng.below(algo_names.len())];
    let algo_spec = if matches!(name, "cidertf" | "cidertf_m" | "sparq_sgd") && rng.bernoulli(0.5)
    {
        format!("{}:{}", name, 1 + rng.below(8))
    } else {
        name.to_string()
    };
    let mut algo = AlgoConfig::by_name(&algo_spec).unwrap();
    if rng.bernoulli(0.3) {
        algo.compressor = Compressor::TopK { ratio: 2 + rng.below(62) as u32 };
    }
    let loss = if rng.bernoulli(0.5) { Loss::Logit } else { Loss::Ls };
    let datasets = ["synthetic", "tiny", "mimic_like"];
    let topologies =
        [Topology::Ring, Topology::Star, Topology::Complete, Topology::Chain, Topology::Torus];
    let fault = rng.bernoulli(0.5).then(|| FaultConfig {
        seed: rng.next_u64(),
        drop_rate: rng.uniform() * 0.5,
        burst_rate: rng.uniform() * 0.1,
        latency_base_s: rng.uniform() * 0.1,
        bandwidth_bps: if rng.bernoulli(0.5) { 1e6 } else { 0.0 },
        churn_rate: rng.uniform() * 0.3,
        churn_period: 1 + rng.below(100),
        straggler_ids: vec![rng.below(8)],
        ..Default::default()
    });
    let driver = if fault.is_some() {
        if rng.bernoulli(0.5) {
            DriverKind::Sim
        } else {
            DriverKind::Async
        }
    } else {
        [DriverKind::Sequential, DriverKind::Parallel, DriverKind::Sim, DriverKind::Async]
            [rng.below(4)]
    };
    let partitioner = match rng.below(3) {
        0 => Partitioner::Even,
        1 => Partitioner::Skewed(0.25 + rng.uniform() * 2.0),
        _ => Partitioner::SiteVocab(0.1 + rng.uniform() * 0.8),
    };
    let aggregator = match rng.below(3) {
        0 => Aggregator::Mean,
        1 => Aggregator::TrimmedMean(rng.uniform() * 0.49),
        _ => Aggregator::CoordinateMedian,
    };
    // Byzantine schedules need a publish-intercepting driver (seq/sim)
    let adversary = (matches!(driver, DriverKind::Sequential | DriverKind::Sim)
        && rng.bernoulli(0.4))
    .then(|| match rng.below(3) {
        0 => AdversarySchedule::sign_flip(rng.uniform()),
        1 => AdversarySchedule::scaled_noise(rng.uniform()),
        _ => AdversarySchedule::stale_replay(rng.uniform()),
    });
    ExperimentSpec {
        dataset: datasets[rng.below(3)].to_string(),
        loss,
        algo,
        topology: topologies[rng.below(5)],
        k: 1 + rng.below(12),
        rank: 1 + rng.below(32),
        fiber_samples: 1 + rng.below(512),
        gamma: rng.uniform() * 8.0 + 1e-3,
        epochs: 1 + rng.below(20),
        iters_per_epoch: 1 + rng.below(500),
        seed: rng.next_u64(),
        eval_batch: 1 + rng.below(1024),
        init_scale: rng.uniform_f32(),
        trigger_lambda0_scale: rng.uniform() * 2.0,
        trigger_alpha: 1.0 + rng.uniform(),
        sim_iter_s: rng.uniform(),
        compute_threads: 1 + rng.below(8),
        fault,
        partitioner,
        aggregator,
        adversary,
        driver,
        transport: if rng.bernoulli(0.5) { "tcp" } else { "uds" }.to_string(),
        backend: if rng.bernoulli(0.8) { "native" } else { "pjrt" }.to_string(),
        eval_every: 1 + rng.below(3),
        stop: StopRule {
            target_loss: rng.bernoulli(0.5).then(|| rng.uniform()),
            max_bytes: rng.bernoulli(0.5).then(|| rng.next_u64()),
        },
    }
}

#[test]
fn spec_json_roundtrip_property() {
    forall(
        "experiment spec JSON round-trip",
        60,
        gen_spec,
        |spec, _| {
            let pretty = spec.to_json().to_pretty_string();
            let back = ExperimentSpec::from_json_str(&pretty)
                .map_err(|e| format!("parse failed: {e:#}\n{pretty}"))?;
            if &back != spec {
                return Err(format!("round-trip mismatch:\n{back:?}\nvs\n{spec:?}"));
            }
            // compact form too
            let compact = spec.to_json().to_string();
            let back2 = ExperimentSpec::from_json_str(&compact)
                .map_err(|e| format!("compact parse failed: {e:#}"))?;
            if &back2 != spec {
                return Err("compact round-trip mismatch".to_string());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// scenario / registry error paths
// ---------------------------------------------------------------------

#[test]
fn scenario_parse_error_paths() {
    // typo'd algorithm: did-you-mean from the registry
    let err = format!("{:#}", Scenario::parse("cidrtf:4").unwrap_err());
    assert!(err.contains("cidertf"), "no suggestion in: {err}");

    // typo'd network scenario
    let err = format!("{:#}", Scenario::parse("cidertf:4@lozzy:0.2").unwrap_err());
    assert!(err.contains("lossy"), "no suggestion in: {err}");

    // bad numeric arguments
    assert!(Scenario::parse("cidertf:x").is_err());
    assert!(Scenario::parse("cidertf:4@lossy:abc").is_err());
    assert!(Scenario::parse("cidertf:4@lossy:1.5").is_err(), "drop rate out of range");

    // structural errors
    assert!(Scenario::parse("").is_err());
    assert!(Scenario::parse("cidertf@ideal@seq@extra").is_err());
    assert!(Scenario::parse("cidertf:4@lossy:0.2@seq").is_err(), "faults need sim/async");
    assert!(Scenario::parse("cidertf:4@lossy:0.2@par").is_err(), "faults need sim/async");

    // driver typo
    let err = format!("{:#}", Scenario::parse("cidertf@ideal@asyncc").unwrap_err());
    assert!(err.contains("async"), "no driver suggestion in: {err}");
}

#[test]
fn every_registry_name_resolves() {
    for name in registry::algos().names() {
        assert!(AlgoConfig::by_name(name).is_ok(), "algo {name}");
    }
    for name in registry::networks().names() {
        assert!(FaultConfig::by_name(name).is_ok(), "network {name}");
    }
    for name in registry::drivers().names() {
        assert!(DriverKind::from_name(name).is_ok(), "driver {name}");
    }
    for name in registry::losses().names() {
        assert!(Loss::from_name(name).is_ok(), "loss {name}");
    }
    for name in registry::topologies().names() {
        assert!(Topology::from_name(name).is_ok(), "topology {name}");
    }
    for name in registry::compressors().names() {
        assert!(Compressor::by_name(name).is_ok(), "compressor {name}");
    }
}

// ---------------------------------------------------------------------
// session runs, observers, stop rules
// ---------------------------------------------------------------------

fn tiny_spec(algo: AlgoConfig, k: usize, driver: DriverKind) -> ExperimentSpec {
    ExperimentSpec::builder("tiny", Loss::Logit, algo)
        .rank(4)
        .fiber_samples(16)
        .k(k)
        .gamma(0.5)
        .iters_per_epoch(50)
        .epochs(4)
        .eval_batch(64)
        .init_scale(0.3)
        .driver(driver)
        .build()
        .unwrap()
}

fn run_spec(spec: &ExperimentSpec, data: &SynthData) -> TrainOutcome {
    let mut backend = NativeBackend::new();
    Session::new(spec.clone()).run_on(data, &mut backend, None).unwrap()
}

#[derive(Default)]
struct CountingObserver {
    run_start: usize,
    run_end: usize,
    rounds: usize,
    evals: usize,
    comm_events: usize,
    comm_bytes_last: u64,
}

impl Observer for CountingObserver {
    fn on_event(&mut self, event: &SessionEvent) -> anyhow::Result<()> {
        match event {
            SessionEvent::RunStart { spec } => {
                assert!(spec.get("algo").is_some(), "RunStart carries the spec");
                self.run_start += 1;
            }
            SessionEvent::RoundEnd { .. } => self.rounds += 1,
            SessionEvent::EvalPoint { .. } => self.evals += 1,
            SessionEvent::CommBytes { total_bytes, .. } => {
                assert!(*total_bytes >= self.comm_bytes_last, "comm bytes must be cumulative");
                self.comm_bytes_last = *total_bytes;
                self.comm_events += 1;
            }
            SessionEvent::RunEnd { .. } => self.run_end += 1,
            _ => {}
        }
        Ok(())
    }
}

#[test]
fn observers_see_the_typed_event_stream() {
    let spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Sim);
    let data = spec.dataset_data().unwrap();
    let mut backend = NativeBackend::new();
    // run once with a counting observer wired in via a channel-free trick:
    // assertions live inside the observer, counts are checked on RunEnd
    struct Final(CountingObserver, usize);
    impl Observer for Final {
        fn on_event(&mut self, event: &SessionEvent) -> anyhow::Result<()> {
            self.0.on_event(event)?;
            if let SessionEvent::RunEnd { .. } = event {
                assert_eq!(self.0.run_start, 1);
                assert_eq!(self.0.evals, 4 + 1, "one initial + one per epoch");
                assert_eq!(self.0.rounds, self.1, "one RoundEnd per iteration");
                assert!(self.0.comm_events > 0, "no CommBytes events");
                assert!(self.0.comm_bytes_last > 0);
            }
            Ok(())
        }
    }
    let total_iters = spec.epochs * spec.iters_per_epoch;
    let out = Session::new(spec)
        .observe(Box::new(Final(CountingObserver::default(), total_iters)))
        .run_on(&data, &mut backend, None)
        .unwrap();
    assert!(out.record.final_loss().is_finite());
}

#[test]
fn session_seq_matches_legacy_train_shim() {
    let spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Sequential);
    let data = spec.dataset_data().unwrap();
    let cfg = spec.to_train_config();
    let mut b1 = NativeBackend::new();
    let legacy = train(&cfg, &data, &mut b1, None).unwrap();
    let session = run_spec(&spec, &data);
    for (a, b) in legacy.factors.mats.iter().zip(session.factors.mats.iter()) {
        assert_eq!(a.data, b.data, "Session seq diverged from engine::train");
    }
    assert_eq!(legacy.record.total.bytes, session.record.total.bytes);
    assert_eq!(legacy.record.net.delivered, session.record.net.delivered);
}

#[test]
fn stop_rules_halt_early() {
    // an unreachably generous loss target stops at the first eval point
    let mut spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Sim);
    spec.stop.target_loss = Some(f64::MAX);
    let data = spec.dataset_data().unwrap();
    let out = run_spec(&spec, &data);
    assert_eq!(out.record.points.len(), 2, "initial point + the stopping epoch");

    // a one-byte budget stops at the first eval point after any traffic
    let mut spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Sim);
    spec.stop.max_bytes = Some(1);
    let out = run_spec(&spec, &data);
    assert!(out.record.points.len() < 5, "budget rule never fired");
    assert!(out.record.total.bytes >= 1);
}

#[test]
fn eval_every_thins_the_curve_but_keeps_the_final_point() {
    let mut spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Sim);
    spec.eval_every = 2;
    let data = spec.dataset_data().unwrap();
    let out = run_spec(&spec, &data);
    let epochs: Vec<usize> = out.record.points.iter().map(|p| p.epoch).collect();
    assert_eq!(epochs, vec![0, 2, 4]);

    // a cadence that does not divide the epoch count still records the end
    let mut spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Sim);
    spec.epochs = 3;
    spec.eval_every = 2;
    let out = run_spec(&spec, &data);
    let epochs: Vec<usize> = out.record.points.iter().map(|p| p.epoch).collect();
    assert_eq!(epochs, vec![0, 2, 3]);
}

// ---------------------------------------------------------------------
// checkpoint / resume — the bit-identity acceptance criterion
// ---------------------------------------------------------------------

fn ckpt_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("cidertf_session_api_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}_{}.ckpt.json", std::process::id()))
}

/// Run `spec` truncated to `cut` epochs with checkpointing, then resume
/// from the checkpoint extended back to the full epoch count; return the
/// resumed outcome.
fn interrupted_run(spec: &ExperimentSpec, cut: usize, data: &SynthData, tag: &str) -> TrainOutcome {
    let path = ckpt_path(tag);
    let mut truncated = spec.clone();
    truncated.epochs = cut;
    let mut backend = NativeBackend::new();
    Session::new(truncated)
        .checkpoint_every(&path, 1)
        .run_on(data, &mut backend, None)
        .unwrap();

    let mut resumed = Session::resume_from(&path).unwrap();
    assert_eq!(resumed.spec().epochs, cut, "checkpoint preserves the truncated spec");
    resumed.spec_mut().epochs = spec.epochs;
    let mut backend = NativeBackend::new();
    let out = resumed.run_on(data, &mut backend, None).unwrap();
    std::fs::remove_file(&path).ok();
    out
}

fn assert_bit_identical(full: &TrainOutcome, resumed: &TrainOutcome, virtual_time: bool) {
    for (m, (a, b)) in full.factors.mats.iter().zip(resumed.factors.mats.iter()).enumerate() {
        assert_eq!(a.data, b.data, "factors diverged after resume (mode {m})");
    }
    assert_eq!(full.record.points.len(), resumed.record.points.len());
    for (p, q) in full.record.points.iter().zip(resumed.record.points.iter()) {
        assert_eq!(p.epoch, q.epoch);
        assert_eq!(p.iter, q.iter);
        assert_eq!(p.loss, q.loss, "loss diverged at epoch {}", p.epoch);
        assert_eq!(p.bytes, q.bytes, "comm bytes diverged at epoch {}", p.epoch);
        if virtual_time {
            assert_eq!(p.time_s, q.time_s, "virtual clock diverged at epoch {}", p.epoch);
        }
    }
    assert_eq!(full.record.total.bytes, resumed.record.total.bytes);
    assert_eq!(full.record.total.messages, resumed.record.total.messages);
    assert_eq!(full.record.total.triggered, resumed.record.total.triggered);
    assert_eq!(full.record.total.suppressed, resumed.record.total.suppressed);
    assert_eq!(full.record.net.delivered, resumed.record.net.delivered);
    assert_eq!(full.record.net.dropped, resumed.record.net.dropped);
    assert_eq!(full.record.net.offline_rounds, resumed.record.net.offline_rounds);
}

#[test]
fn checkpoint_resume_bit_identical_ideal_network() {
    let spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Sim);
    let data = spec.dataset_data().unwrap();
    let full = run_spec(&spec, &data);
    let resumed = interrupted_run(&spec, 2, &data, "ideal");
    assert_bit_identical(&full, &resumed, true);
}

#[test]
fn checkpoint_resume_bit_identical_faulty_network() {
    let mut spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Sim);
    spec.fault = Some(FaultConfig {
        seed: 1234,
        drop_rate: 0.3,
        burst_rate: 0.05,
        churn_rate: 0.2,
        churn_period: 20,
        straggler_ids: vec![1],
        latency_base_s: 0.01,
        bandwidth_bps: 1e6,
        ..Default::default()
    });
    let data = spec.dataset_data().unwrap();
    let full = run_spec(&spec, &data);
    assert!(full.record.net.dropped > 0, "fault envelope not exercised");
    assert!(full.record.net.offline_rounds > 0, "churn not exercised");
    let resumed = interrupted_run(&spec, 2, &data, "faulty");
    assert_bit_identical(&full, &resumed, true);
}

#[test]
fn checkpoint_resume_bit_identical_momentum_and_ef() {
    // momentum velocities and error-feedback residuals/shadows must also
    // ride through the checkpoint (centralized CiderTF exercises EF)
    let spec = tiny_spec(AlgoConfig::centralized_cidertf(), 1, DriverKind::Sim);
    let data = spec.dataset_data().unwrap();
    let full = run_spec(&spec, &data);
    let resumed = interrupted_run(&spec, 2, &data, "ef");
    assert_bit_identical(&full, &resumed, true);

    let spec = tiny_spec(AlgoConfig::cidertf_m(2), 4, DriverKind::Sim);
    let full = run_spec(&spec, &data);
    let resumed = interrupted_run(&spec, 2, &data, "momentum");
    assert_bit_identical(&full, &resumed, true);
}

#[test]
fn checkpoint_resume_sequential_wall_clock_factors_match() {
    // wall-clock timestamps legitimately differ across process restarts;
    // factors and losses must not
    let spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Sequential);
    let data = spec.dataset_data().unwrap();
    let full = run_spec(&spec, &data);
    let resumed = interrupted_run(&spec, 2, &data, "seq");
    for (a, b) in full.factors.mats.iter().zip(resumed.factors.mats.iter()) {
        assert_eq!(a.data, b.data, "sequential resume diverged");
    }
    for (p, q) in full.record.points.iter().zip(resumed.record.points.iter()) {
        assert_eq!(p.loss, q.loss);
        assert_eq!(p.bytes, q.bytes);
    }
}

#[test]
fn async_driver_rejects_checkpointing() {
    let mut spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Async);
    spec.fault = Some(FaultConfig::lossy(0.1));
    let data = spec.dataset_data().unwrap();
    let mut backend = NativeBackend::new();
    let err = Session::new(spec)
        .checkpoint_every(ckpt_path("async_reject"), 1)
        .run_on(&data, &mut backend, None);
    assert!(err.is_err(), "async driver must reject checkpointing");
}

#[test]
fn delegated_drivers_reject_unsupported_session_features() {
    // stop rules and eval cadence are loop-level features; the async/par
    // drivers run their loops internally, so silently ignoring them
    // would run a different experiment than specified
    let data = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Sim).dataset_data().unwrap();

    let mut spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Async);
    spec.stop.target_loss = Some(1.0);
    let mut backend = NativeBackend::new();
    let err = Session::new(spec).run_on(&data, &mut backend, None);
    assert!(err.is_err(), "async driver must reject stop rules");

    let mut spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Parallel);
    spec.eval_every = 2;
    let err = Session::new(spec).run_on(&data, &mut backend, None);
    assert!(err.is_err(), "par driver must reject eval_every > 1");
}

#[test]
fn spec_json_rejects_unknown_keys_with_hint() {
    let spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Sim);
    let good = spec.to_json().to_string();

    // top-level typo
    let bad = good.replace("\"epochs\"", "\"epochz\"");
    let err = format!("{:#}", ExperimentSpec::from_json_str(&bad).unwrap_err());
    assert!(err.contains("epochz") && err.contains("epochs"), "{err}");

    // fault-envelope typo must not silently mean an ideal link
    let mut spec = tiny_spec(AlgoConfig::cidertf(2), 4, DriverKind::Sim);
    spec.fault = Some(FaultConfig::lossy(0.5));
    let bad = spec.to_json().to_string().replace("\"drop_rate\"", "\"drop_rte\"");
    let err = format!("{:#}", ExperimentSpec::from_json_str(&bad).unwrap_err());
    assert!(err.contains("drop_rte") && err.contains("drop_rate"), "{err}");
}
