//! Differential tests: PJRT artifacts vs the native Rust mirror.
//!
//! These close the cross-language loop — the same HLO text the Python
//! tests validated is loaded through the `xla` crate and must agree with
//! the pure-Rust implementation on random inputs.
//!
//! Requires `make artifacts` (skips gracefully when absent so `cargo test`
//! stays runnable on a fresh checkout).

use cidertf::losses::Loss;
use cidertf::runtime::native::NativeBackend;
use cidertf::runtime::{default_artifact_dir, ComputeBackend, Manifest, PjrtBackend};
use cidertf::util::mat::Mat;
use cidertf::util::rng::Rng;

fn backend_or_skip() -> Option<PjrtBackend> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(PjrtBackend::new(&dir).expect("pjrt backend"))
}

fn randmat(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
    Mat::rand_normal(rows, cols, 0.4, rng)
}

#[test]
fn grad_artifacts_match_native_d3() {
    let Some(mut pjrt) = backend_or_skip() else { return };
    let mut native = NativeBackend::new();
    let (i, s, r) = (32, 16, 4);
    let mut rng = Rng::new(77);
    for loss in [Loss::Ls, Loss::Logit] {
        let xs: Vec<f32> = (0..i * s).map(|_| if rng.bernoulli(0.1) { 1.0 } else { 0.0 }).collect();
        let a = randmat(i, r, &mut rng);
        let u1 = randmat(s, r, &mut rng);
        let u2 = randmat(s, r, &mut rng);
        let (g_p, l_p) = pjrt.grad(loss, &xs, i, s, &a, &[&u1, &u2], 2.5).unwrap();
        let (g_n, l_n) = native.grad(loss, &xs, i, s, &a, &[&u1, &u2], 2.5).unwrap();
        assert_eq!(g_p.rows, i);
        assert_eq!(g_p.cols, r);
        for (p, n) in g_p.data.iter().zip(g_n.data.iter()) {
            assert!((p - n).abs() < 1e-3, "{loss:?}: {p} vs {n}");
        }
        let rel = (l_p - l_n).abs() / l_n.abs().max(1.0);
        assert!(rel < 1e-4, "{loss:?} loss {l_p} vs {l_n}");
    }
}

#[test]
fn grad_artifacts_match_native_d4() {
    let Some(mut pjrt) = backend_or_skip() else { return };
    let mut native = NativeBackend::new();
    let (i, s, r) = (64, 32, 8);
    let mut rng = Rng::new(78);
    for loss in [Loss::Ls, Loss::Logit] {
        let xs: Vec<f32> = (0..i * s).map(|_| rng.normal_f32() * 0.3).collect();
        let a = randmat(i, r, &mut rng);
        let us: Vec<Mat> = (0..3).map(|_| randmat(s, r, &mut rng)).collect();
        let refs: Vec<&Mat> = us.iter().collect();
        let (g_p, l_p) = pjrt.grad(loss, &xs, i, s, &a, &refs, 1.0).unwrap();
        let (g_n, l_n) = native.grad(loss, &xs, i, s, &a, &refs, 1.0).unwrap();
        for (p, n) in g_p.data.iter().zip(g_n.data.iter()) {
            assert!((p - n).abs() < 1e-3, "{loss:?}: {p} vs {n}");
        }
        assert!((l_p - l_n).abs() / l_n.abs().max(1.0) < 1e-4);
    }
}

#[test]
fn eval_artifacts_match_native() {
    let Some(mut pjrt) = backend_or_skip() else { return };
    let mut native = NativeBackend::new();
    let (b, r) = (64, 4);
    let mut rng = Rng::new(79);
    for loss in [Loss::Ls, Loss::Logit] {
        let us: Vec<Mat> = (0..3).map(|_| randmat(b, r, &mut rng)).collect();
        let refs: Vec<&Mat> = us.iter().collect();
        let x: Vec<f32> = (0..b).map(|_| if rng.bernoulli(0.2) { 1.0 } else { 0.0 }).collect();
        let l_p = pjrt.eval(loss, &x, &refs).unwrap();
        let l_n = native.eval(loss, &x, &refs).unwrap();
        assert!((l_p - l_n).abs() / l_n.abs().max(1.0) < 1e-4, "{loss:?}: {l_p} vs {l_n}");
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(mut pjrt) = backend_or_skip() else { return };
    let mut rng = Rng::new(80);
    let (i, s, r) = (32, 16, 4);
    let xs: Vec<f32> = vec![0.0; i * s];
    let a = randmat(i, r, &mut rng);
    let u1 = randmat(s, r, &mut rng);
    let u2 = randmat(s, r, &mut rng);
    assert_eq!(pjrt.cached(), 0);
    pjrt.grad(Loss::Ls, &xs, i, s, &a, &[&u1, &u2], 1.0).unwrap();
    assert_eq!(pjrt.cached(), 1);
    pjrt.grad(Loss::Ls, &xs, i, s, &a, &[&u1, &u2], 1.0).unwrap();
    assert_eq!(pjrt.cached(), 1);
    pjrt.grad(Loss::Logit, &xs, i, s, &a, &[&u1, &u2], 1.0).unwrap();
    assert_eq!(pjrt.cached(), 2);
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let Some(mut pjrt) = backend_or_skip() else { return };
    let mut rng = Rng::new(81);
    let a = randmat(7, 3, &mut rng);
    let u = randmat(5, 3, &mut rng);
    let xs = vec![0.0f32; 35];
    let err = pjrt.grad(Loss::Ls, &xs, 7, 5, &a, &[&u, &u], 1.0).unwrap_err();
    assert!(err.to_string().contains("not in manifest"), "{err}");
}

#[test]
fn manifest_covers_all_experiment_shapes() {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let m = Manifest::load(&dir).unwrap();
    // every (dataset, K) patient-mode shard size + feature dims, both losses
    for loss in [Loss::Ls, Loss::Logit] {
        for i in [4096usize, 512, 256, 128, 4352, 544, 272, 136, 320, 8192, 1024, 384] {
            let name = Manifest::grad_name(loss, i, 256, 16, 3);
            assert!(m.has(&name), "missing {name}");
        }
        assert!(m.has(&Manifest::eval_name(loss, 8192, 16, 3)));
    }
}
