//! Steady-state allocation gate for the engine's inner loop.
//!
//! The blocked compute core promises that after warmup,
//! `ClientState::local_step` through `NativeBackend::grad_into` performs
//! **zero heap allocations per call**: the fiber sample, the dense slice
//! gather, the Khatri-Rao row gathers, the gradient panels, and the
//! momentum update all land in buffers owned by the client/backend. This
//! test wraps the global allocator in a counter and asserts exactly that.
//! A second phase asserts the same for a robust consensus round
//! ([`cidertf::gossip::Aggregator`] trimmed-mean and median paths), whose
//! per-coordinate scratch lives in a warmed thread-local.
//!
//! (Own integration-test crate so the counting allocator cannot interfere
//! with any other test binary.)

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use cidertf::compress::Compressor;
use cidertf::engine::client::ClientState;
use cidertf::gossip::{Aggregator, EstimateState};
use cidertf::losses::Loss;
use cidertf::runtime::native::NativeBackend;
use cidertf::tensor::partition::partition_shared;
use cidertf::tensor::synth::SynthConfig;
use cidertf::util::mat::Mat;

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

#[test]
fn local_step_steady_state_is_allocation_free() {
    let data = SynthConfig::tiny(11).generate();
    let shards = partition_shared(&data.tensor, 1);
    // momentum on: the momentum path must also be in place
    let mut c = ClientState::new(0, shards[0].clone(), 4, 0.2, 123, 16, 32, true, false);
    let mut backend = NativeBackend::new();

    // warmup: every per-mode scratch buffer (xs slice, u gathers, grad
    // panels, fiber sample set) reaches its steady-state capacity
    for t in 0..60 {
        c.local_step(t % 3, Loss::Ls, 16, 0.05, Some(0.9), &mut backend).unwrap();
    }

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for t in 0..300 {
        c.local_step(t % 3, Loss::Ls, 16, 0.05, Some(0.9), &mut backend).unwrap();
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state local_step allocated {} time(s) over 300 steps",
        after - before
    );

    // --- phase 2: a robust consensus round is also allocation-free once
    // the per-thread scratch (value buffer + slot map) is warm. Same
    // #[test] as phase 1 on purpose: a second test fn would run on its
    // own harness thread and pollute the measurement windows with its
    // setup allocations.
    let init: Vec<Option<Mat>> =
        vec![None, Some(Mat::from_vec(32, 4, (0..128).map(|i| i as f32 * 0.01).collect()))];
    let mut est = EstimateState::new(0, &[1, 2, 3], &init);
    // perturb one neighbor so the per-coordinate sorts do real work
    let delta = Compressor::None.compress(&Mat::from_vec(32, 4, vec![0.5; 128]));
    est.apply_delta(2, 1, &delta);
    let mut a = Mat::from_vec(32, 4, vec![1.0; 128]);
    let weights = vec![0.25f64; 4];
    let trimmed = Aggregator::TrimmedMean(0.25);
    let median = Aggregator::CoordinateMedian;

    for _ in 0..4 {
        trimmed.consensus_into(&est, &mut a, 1, &[1, 2, 3], &weights, 0.05);
        median.consensus_into(&est, &mut a, 1, &[1, 2, 3], &weights, 0.05);
    }

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..200 {
        trimmed.consensus_into(&est, &mut a, 1, &[1, 2, 3], &weights, 0.05);
        median.consensus_into(&est, &mut a, 1, &[1, 2, 3], &weights, 0.05);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "robust consensus allocated {} time(s) over 400 rounds",
        after - before
    );
}
