//! Deployment-plane integration tests: a loopback fleet of `node`
//! daemons talking over real sockets must reproduce the in-process sim
//! driver **bit-for-bit** — the merged fleet checkpoint and the sim
//! driver's final checkpoint are compared as raw bytes.

use std::path::{Path, PathBuf};
use std::thread;

use cidertf::engine::checkpoint::{write_checkpoint, SessionState};
use cidertf::engine::session::Session;
use cidertf::engine::spec::ExperimentSpec;
use cidertf::engine::AlgoConfig;
use cidertf::losses::Loss;
use cidertf::net::driver::DriverKind;
use cidertf::node::daemon::run_node_with_listener;
use cidertf::node::fleet::{merge_outcomes, FleetConfig, NodeAddr};
use cidertf::node::transport::{DialOpts, Listener, TransportKind};

fn tmp_dir(name: &str) -> PathBuf {
    let base = format!("cidertf_node_fleet_{name}_{}", std::process::id());
    let dir = std::env::temp_dir().join(base);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn node_spec(k: usize, transport: &str) -> ExperimentSpec {
    ExperimentSpec::builder("tiny", Loss::Logit, AlgoConfig::cidertf(2))
        .k(k)
        .rank(4)
        .fiber_samples(16)
        .gamma(0.5)
        .iters_per_epoch(12)
        .epochs(1)
        .eval_batch(64)
        .driver(DriverKind::Node)
        .transport(transport)
        .build()
        .unwrap()
}

/// Bind one listener per node (OS-assigned TCP ports / per-test UDS
/// paths), run every node on its own thread, and merge the outcomes.
fn run_fleet(
    spec: &ExperimentSpec,
    kind: TransportKind,
    dir: &Path,
) -> (ExperimentSpec, SessionState) {
    let mut listeners = Vec::new();
    let mut nodes = Vec::new();
    for id in 0..spec.k {
        let addr = match kind {
            TransportKind::Tcp => "127.0.0.1:0".to_string(),
            TransportKind::Uds => dir.join(format!("node{id}.sock")).display().to_string(),
        };
        let l = Listener::bind(kind, &addr).unwrap();
        nodes.push(NodeAddr { id, addr: l.local_addr().unwrap() });
        listeners.push(l);
    }
    let d = DialOpts::default();
    let cfg = FleetConfig {
        spec: spec.clone(),
        nodes,
        read_timeout_ms: d.read_timeout_ms,
        write_timeout_ms: d.write_timeout_ms,
        dial_timeout_ms: d.dial_timeout_ms,
        backoff_ms: d.backoff_ms,
    };
    let handles: Vec<_> = listeners
        .into_iter()
        .enumerate()
        .map(|(id, l)| {
            let cfg = cfg.clone();
            thread::spawn(move || run_node_with_listener(&cfg, id, l, None))
        })
        .collect();
    let mut outcomes = Vec::new();
    for (id, h) in handles.into_iter().enumerate() {
        match h.join().expect("node thread panicked") {
            Ok(o) => outcomes.push(o),
            Err(e) => panic!("node {id} failed: {e:#}"),
        }
    }
    merge_outcomes(spec, &outcomes).unwrap()
}

fn sim_checkpoint(spec: &ExperimentSpec, path: &Path) {
    let mut sim_spec = spec.clone();
    sim_spec.driver = DriverKind::Sim;
    Session::new(sim_spec).checkpoint_every(path, 1).run().unwrap();
}

#[test]
fn tcp_fleet_checkpoint_matches_sim_driver_bytes() {
    let dir = tmp_dir("tcp");
    let spec = node_spec(3, "tcp");

    let (merged_spec, state) = run_fleet(&spec, TransportKind::Tcp, &dir);
    let fleet_ckpt = dir.join("fleet.ckpt.json");
    write_checkpoint(&fleet_ckpt, &merged_spec, &state).unwrap();

    let sim_ckpt = dir.join("sim.ckpt.json");
    sim_checkpoint(&spec, &sim_ckpt);

    let fleet_bytes = std::fs::read(&fleet_ckpt).unwrap();
    let sim_bytes = std::fs::read(&sim_ckpt).unwrap();
    assert!(
        fleet_bytes == sim_bytes,
        "3-node TCP fleet checkpoint differs from the sim driver's ({} vs {} bytes)",
        fleet_bytes.len(),
        sim_bytes.len()
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn uds_fleet_checkpoint_matches_sim_driver_bytes() {
    let dir = tmp_dir("uds");
    let spec = node_spec(2, "uds");

    let (merged_spec, state) = run_fleet(&spec, TransportKind::Uds, &dir);
    let fleet_ckpt = dir.join("fleet.ckpt.json");
    write_checkpoint(&fleet_ckpt, &merged_spec, &state).unwrap();

    let sim_ckpt = dir.join("sim.ckpt.json");
    sim_checkpoint(&spec, &sim_ckpt);

    assert!(
        std::fs::read(&fleet_ckpt).unwrap() == std::fs::read(&sim_ckpt).unwrap(),
        "2-node UDS fleet checkpoint differs from the sim driver's"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn dial_error_names_the_unreachable_address() {
    // a port that was just released — nothing listens there
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let l0 = Listener::bind(TransportKind::Tcp, "127.0.0.1:0").unwrap();
    let addr0 = l0.local_addr().unwrap();
    let cfg = FleetConfig {
        spec: node_spec(2, "tcp"),
        nodes: vec![
            NodeAddr { id: 0, addr: addr0 },
            NodeAddr { id: 1, addr: dead.clone() },
        ],
        read_timeout_ms: 1_000,
        write_timeout_ms: 1_000,
        dial_timeout_ms: 200,
        backoff_ms: 20,
    };
    let err = format!("{:#}", run_node_with_listener(&cfg, 0, l0, None).unwrap_err());
    assert!(err.contains("cannot reach peer"), "{err}");
    assert!(err.contains(&dead), "error must name the unreachable address: {err}");
    assert!(err.contains("connecting to node 1"), "{err}");
}
