//! Adversary & heterogeneity plane — the tentpole acceptance tests:
//! (1) trimmed-mean consensus survives 20% sign-flip adversaries (final
//! loss within 2x of the honest-mean baseline) while the plain mean
//! measurably degrades; (2) checkpoint/resume stays bit-identical under
//! an adversarial run on a faulty network (including the stateful
//! `stale_replay` attack, whose replay buffer rides the checkpoint);
//! (3) the sweep aggregate over an (adversary x aggregator) grid is
//! byte-identical for 1 vs N workers. Plus seeded property tests for the
//! robust aggregator cores (permutation invariance, range bounds,
//! `trimmed_mean(0) == mean`, non-finite stability).

use std::path::PathBuf;

use cidertf::adversary::AdversarySchedule;
use cidertf::data::Dataset;
use cidertf::engine::session::{Observer, Session, SessionEvent};
use cidertf::engine::spec::ExperimentSpec;
use cidertf::engine::{AlgoConfig, TrainOutcome};
use cidertf::gossip::robust::{coordinate_median_of, trimmed_mean_of};
use cidertf::gossip::Aggregator;
use cidertf::losses::Loss;
use cidertf::net::driver::DriverKind;
use cidertf::net::sim::FaultConfig;
use cidertf::runtime::native::NativeBackend;
use cidertf::sweep::{self, SweepOptions, SweepSpec};
use cidertf::tensor::partition::Partitioner;
use cidertf::topology::Topology;
use cidertf::util::order::nan_last_f32;
use cidertf::util::propcheck::forall;
use cidertf::util::rng::Rng;

// ---------------------------------------------------------------------
// shared setup
// ---------------------------------------------------------------------

/// k=5 on the complete graph with master seed 5: at fraction 0.2 the
/// unit-hash subset marks exactly client 1 Byzantine — one adversary,
/// four honest clients, every honest client sees the corrupted delta.
fn robust_spec(aggregator: Aggregator, adversary: Option<AdversarySchedule>) -> ExperimentSpec {
    ExperimentSpec::builder("tiny", Loss::Logit, AlgoConfig::cidertf(2))
        .rank(4)
        .fiber_samples(16)
        .k(5)
        .topology(Topology::Complete)
        .gamma(0.5)
        .iters_per_epoch(50)
        .epochs(4)
        .eval_batch(64)
        .init_scale(0.3)
        .seed(5)
        .driver(DriverKind::Sequential)
        .aggregator(aggregator)
        .adversary(adversary)
        .build()
        .unwrap()
}

fn run_spec(spec: &ExperimentSpec, data: &Dataset) -> TrainOutcome {
    let mut backend = NativeBackend::new();
    Session::new(spec.clone()).run_on(data, &mut backend, None).unwrap()
}

fn sign_flip_20() -> Option<AdversarySchedule> {
    Some(AdversarySchedule::sign_flip(0.2))
}

// ---------------------------------------------------------------------
// (1) convergence under attack
// ---------------------------------------------------------------------

/// Counts `AdversarialAct` events and cross-checks them against the
/// `NetStats` counter at `RunEnd`.
#[derive(Default)]
struct AdvObserver {
    acts: u64,
}

impl Observer for AdvObserver {
    fn on_event(&mut self, event: &SessionEvent) -> anyhow::Result<()> {
        match event {
            SessionEvent::AdversarialAct { client, mode, kind, .. } => {
                assert_eq!(*client, 1, "only client 1 is Byzantine under seed 5");
                assert_ne!(*mode, 0, "the patient mode never travels, so it cannot be corrupted");
                assert_eq!(*kind, "sign_flip");
                self.acts += 1;
            }
            SessionEvent::RunEnd { record } => {
                assert!(self.acts > 0, "no AdversarialAct events observed");
                assert_eq!(
                    self.acts, record.net.adversarial,
                    "event count must match the NetStats adversarial counter"
                );
            }
            _ => {}
        }
        Ok(())
    }
}

#[test]
fn trimmed_mean_survives_sign_flip_adversaries() {
    let honest = robust_spec(Aggregator::Mean, None);
    // the pinned Byzantine subset the whole test keys on
    let sched = robust_spec(Aggregator::Mean, sign_flip_20()).adversary_schedule().unwrap();
    assert_eq!(sched.adversarial_clients(5), vec![1], "seed-5 subset drifted");

    let data = honest.dataset_data().unwrap();
    let honest_out = run_spec(&honest, &data);
    let honest_loss = honest_out.record.final_loss();
    assert!(honest_loss.is_finite() && honest_loss > 0.0, "honest baseline broken: {honest_loss}");
    assert_eq!(honest_out.record.net.adversarial, 0, "honest run counted attacks");

    // plain mean trusts every neighbor linearly: the mirrored estimate a
    // sign-flip adversary broadcasts drags the whole fleet
    let mean_out = run_spec(&robust_spec(Aggregator::Mean, sign_flip_20()), &data);
    assert!(mean_out.record.net.adversarial > 0, "attack never fired");
    let mean_loss = mean_out.record.final_loss();

    // trimmed mean drops one value per extreme of the 5-value coordinate
    // set, which is exactly where the mirrored estimate lands
    let trimmed = robust_spec(Aggregator::TrimmedMean(0.25), sign_flip_20());
    let mut backend = NativeBackend::new();
    let trim_out = Session::new(trimmed)
        .observe(Box::new(AdvObserver::default()))
        .run_on(&data, &mut backend, None)
        .unwrap();
    assert!(trim_out.record.net.adversarial > 0, "attack never fired under trimmed mean");
    let trim_loss = trim_out.record.final_loss();

    assert!(
        trim_loss.is_finite() && trim_loss <= 2.0 * honest_loss,
        "trimmed mean did not hold under attack: {trim_loss} vs honest {honest_loss}"
    );
    assert!(
        mean_loss.is_nan() || mean_loss > 1.05 * honest_loss,
        "plain mean did not degrade under attack: {mean_loss} vs honest {honest_loss}"
    );
    assert!(
        mean_loss.is_nan() || trim_loss < mean_loss,
        "robust aggregation did not beat the naive mean: {trim_loss} vs {mean_loss}"
    );
}

#[test]
fn trimmed_mean_zero_dispatches_bit_identically_to_mean() {
    // β = 0 is *defined* as the weighted-mean code path, so an honest run
    // must be bit-identical, not merely close
    let mean_spec = robust_spec(Aggregator::Mean, None);
    let data = mean_spec.dataset_data().unwrap();
    let a = run_spec(&mean_spec, &data);
    let b = run_spec(&robust_spec(Aggregator::TrimmedMean(0.0), None), &data);
    for (m, (x, y)) in a.factors.mats.iter().zip(b.factors.mats.iter()).enumerate() {
        assert_eq!(x.data, y.data, "trimmed_mean:0 diverged from mean (mode {m})");
    }
    for (p, q) in a.record.points.iter().zip(b.record.points.iter()) {
        assert_eq!(p.loss.to_bits(), q.loss.to_bits());
    }
}

#[test]
fn non_iid_partitioners_are_deterministic_and_change_the_run() {
    let mut skewed = robust_spec(Aggregator::Mean, None);
    skewed.partitioner = Partitioner::SiteVocab(0.5);
    let data = skewed.dataset_data().unwrap();
    let a = run_spec(&skewed, &data);
    let b = run_spec(&skewed, &data);
    assert!(a.record.final_loss().is_finite());
    for (x, y) in a.factors.mats.iter().zip(b.factors.mats.iter()) {
        assert_eq!(x.data, y.data, "site_vocab partitioning is not deterministic");
    }
    // a different partitioner means different local data, hence a
    // genuinely different trajectory
    let even = run_spec(&robust_spec(Aggregator::Mean, None), &data);
    assert!(
        a.factors.mats.iter().zip(even.factors.mats.iter()).any(|(x, y)| x.data != y.data),
        "site_vocab run is indistinguishable from the even partition"
    );
}

// ---------------------------------------------------------------------
// (2) checkpoint/resume bit-identity under adversarial faulty runs
// ---------------------------------------------------------------------

fn ckpt_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("cidertf_robustness_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{tag}_{}.ckpt.json", std::process::id()))
}

/// Run `spec` truncated to `cut` epochs with checkpointing, then resume
/// extended back to the full epoch count (same shape as the session-API
/// checkpoint tests).
fn interrupted_run(spec: &ExperimentSpec, cut: usize, data: &Dataset, tag: &str) -> TrainOutcome {
    let path = ckpt_path(tag);
    let mut truncated = spec.clone();
    truncated.epochs = cut;
    let mut backend = NativeBackend::new();
    Session::new(truncated).checkpoint_every(&path, 1).run_on(data, &mut backend, None).unwrap();

    let mut resumed = Session::resume_from(&path).unwrap();
    resumed.spec_mut().epochs = spec.epochs;
    let mut backend = NativeBackend::new();
    let out = resumed.run_on(data, &mut backend, None).unwrap();
    std::fs::remove_file(&path).ok();
    out
}

fn assert_bit_identical(full: &TrainOutcome, resumed: &TrainOutcome) {
    for (m, (a, b)) in full.factors.mats.iter().zip(resumed.factors.mats.iter()).enumerate() {
        assert_eq!(a.data, b.data, "factors diverged after resume (mode {m})");
    }
    assert_eq!(full.record.points.len(), resumed.record.points.len());
    for (p, q) in full.record.points.iter().zip(resumed.record.points.iter()) {
        assert_eq!(p.loss.to_bits(), q.loss.to_bits(), "loss diverged at epoch {}", p.epoch);
        assert_eq!(p.bytes, q.bytes, "comm bytes diverged at epoch {}", p.epoch);
        assert_eq!(p.time_s.to_bits(), q.time_s.to_bits(), "virtual clock diverged");
    }
    assert_eq!(full.record.total.bytes, resumed.record.total.bytes);
    assert_eq!(full.record.net.delivered, resumed.record.net.delivered);
    assert_eq!(full.record.net.dropped, resumed.record.net.dropped);
    assert_eq!(full.record.net.offline_rounds, resumed.record.net.offline_rounds);
    assert_eq!(
        full.record.net.adversarial, resumed.record.net.adversarial,
        "adversarial-act counter diverged after resume"
    );
}

#[test]
fn checkpoint_resume_bit_identical_under_adversarial_faulty_network() {
    // the stateful attack (replay buffer rides the checkpoint) on a
    // faulty network, defended by the median — the worst-case resume
    let mut spec = robust_spec(Aggregator::CoordinateMedian, None);
    spec.adversary = Some(AdversarySchedule::stale_replay(0.2));
    spec.driver = DriverKind::Sim;
    spec.fault = Some(FaultConfig {
        seed: 1234,
        drop_rate: 0.3,
        burst_rate: 0.05,
        churn_rate: 0.2,
        churn_period: 20,
        straggler_ids: vec![1],
        latency_base_s: 0.01,
        bandwidth_bps: 1e6,
        ..Default::default()
    });
    let data = spec.dataset_data().unwrap();
    let full = run_spec(&spec, &data);
    assert!(full.record.net.adversarial > 0, "stale_replay never fired");
    assert!(full.record.net.dropped > 0, "fault envelope not exercised");
    let resumed = interrupted_run(&spec, 2, &data, "stale_faulty");
    assert_bit_identical(&full, &resumed);
}

#[test]
fn checkpoint_resume_bit_identical_sign_flip_trimmed() {
    let mut spec = robust_spec(Aggregator::TrimmedMean(0.25), sign_flip_20());
    spec.driver = DriverKind::Sim;
    let data = spec.dataset_data().unwrap();
    let full = run_spec(&spec, &data);
    assert!(full.record.net.adversarial > 0);
    let resumed = interrupted_run(&spec, 2, &data, "signflip_trim");
    assert_bit_identical(&full, &resumed);
}

// ---------------------------------------------------------------------
// (3) sweep over the (adversary x aggregator) grid
// ---------------------------------------------------------------------

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cidertf_robustness_sweep_{name}"));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn quiet_opts(dir: PathBuf, workers: usize) -> SweepOptions {
    let mut opts = SweepOptions::new(dir, workers);
    opts.quiet = true;
    opts
}

#[test]
fn robustness_grid_aggregate_is_bit_identical_across_workers() {
    // the CI smoke grid: {mean, trimmed_mean} x {honest, sign_flip} over
    // a skewed partition (4 runs)
    let mut spec = SweepSpec::robust_smoke();
    spec.base.backend = "native".to_string();
    let runs = spec.expand().unwrap();
    assert_eq!(runs.len(), 4);
    let mut labels: Vec<String> = runs.iter().map(|r| r.label()).collect();
    labels.sort();
    labels.dedup();
    assert_eq!(labels.len(), 4, "grid cells collide on disk");

    let dir1 = tmp_dir("workers1");
    let out1 = sweep::execute(&spec, &quiet_opts(dir1.clone(), 1), None).unwrap();
    let jsonl1 = std::fs::read(&out1.jsonl_path).unwrap();

    let dir3 = tmp_dir("workers3");
    let out3 = sweep::execute(&spec, &quiet_opts(dir3.clone(), 3), None).unwrap();
    let jsonl3 = std::fs::read(&out3.jsonl_path).unwrap();

    assert!(!jsonl1.is_empty());
    assert_eq!(jsonl1, jsonl3, "robustness-grid aggregate must be worker-count invariant");

    // the aggregate names the robustness axes so grid cells are
    // distinguishable downstream
    let text = String::from_utf8_lossy(&jsonl1).into_owned();
    for key in ["\"aggregator\"", "\"adversary\"", "\"partitioner\"", "\"adversarial\""] {
        assert!(text.contains(key), "aggregate lines lack {key}");
    }
    // adversarial cells attacked, honest cells did not
    for (run, res) in out1.runs.iter().zip(out1.results.iter()) {
        if run.adversary.is_some() {
            assert!(res.record.net.adversarial > 0, "no attacks in {}", run.label());
        } else {
            assert_eq!(res.record.net.adversarial, 0, "attacks in honest {}", run.label());
        }
    }

    std::fs::remove_dir_all(&dir1).ok();
    std::fs::remove_dir_all(&dir3).ok();
}

// ---------------------------------------------------------------------
// robust-aggregator property tests (seeded, reproducible)
// ---------------------------------------------------------------------

fn gen_finite_values(rng: &mut Rng) -> Vec<f32> {
    let n = 1 + rng.below(12);
    (0..n).map(|_| (rng.uniform() * 20.0 - 10.0) as f32).collect()
}

#[test]
fn robust_centers_are_permutation_invariant() {
    forall("robust centers permutation invariance", 200, gen_finite_values, |vals, rng| {
        let beta = rng.uniform() * 0.49;
        let mut a = vals.clone();
        let mut b = vals.clone();
        for i in (1..b.len()).rev() {
            b.swap(i, rng.below(i + 1));
        }
        let (ta, tb) = (trimmed_mean_of(&mut a, beta), trimmed_mean_of(&mut b, beta));
        if ta.to_bits() != tb.to_bits() {
            return Err(format!("trimmed mean order-dependent: {ta} vs {tb} (beta {beta})"));
        }
        let (ma, mb) = (coordinate_median_of(&mut a), coordinate_median_of(&mut b));
        if ma.to_bits() != mb.to_bits() {
            return Err(format!("median order-dependent: {ma} vs {mb}"));
        }
        Ok(())
    });
}

#[test]
fn robust_centers_stay_within_the_input_range() {
    forall("robust centers bounded by input range", 200, gen_finite_values, |vals, rng| {
        let beta = rng.uniform() * 0.49;
        let lo = vals.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let t = trimmed_mean_of(&mut vals.clone(), beta);
        if !(lo..=hi).contains(&t) {
            return Err(format!("trimmed mean {t} outside [{lo}, {hi}] (beta {beta})"));
        }
        let m = coordinate_median_of(&mut vals.clone());
        if !(lo..=hi).contains(&m) {
            return Err(format!("median {m} outside [{lo}, {hi}]"));
        }
        Ok(())
    });
}

#[test]
fn trimmed_mean_beta_zero_is_the_plain_mean_bitwise() {
    forall("trimmed_mean(0) == mean", 200, gen_finite_values, |vals, _| {
        // the oracle mirrors the documented contract: sort (NaN-last),
        // sum in f64, divide — with zero trim that is the plain mean
        let mut sorted = vals.clone();
        sorted.sort_by(nan_last_f32);
        let mean = (sorted.iter().map(|&v| v as f64).sum::<f64>() / sorted.len() as f64) as f32;
        let t = trimmed_mean_of(&mut vals.clone(), 0.0);
        if t.to_bits() != mean.to_bits() {
            return Err(format!("beta=0 is not the plain mean: {t} vs {mean}"));
        }
        Ok(())
    });
}

/// A contaminated coordinate set: `finite` honest values plus up to `g`
/// `-inf` and up to `g` `+inf`/NaN values, with `beta` chosen so exactly
/// `g` values are trimmed from each end.
#[derive(Debug)]
struct Contaminated {
    values: Vec<f32>,
    beta: f64,
    lo: f32,
    hi: f32,
}

fn gen_contaminated(rng: &mut Rng) -> Contaminated {
    let g = 1 + rng.below(3);
    let finite: Vec<f32> =
        (0..2 * g + 1 + rng.below(5)).map(|_| (rng.uniform() * 20.0 - 10.0) as f32).collect();
    let lo = finite.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = finite.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut values = finite;
    for _ in 0..rng.below(g + 1) {
        values.push(f32::NEG_INFINITY);
    }
    for _ in 0..rng.below(g + 1) {
        values.push(if rng.bernoulli(0.5) { f32::INFINITY } else { f32::NAN });
    }
    let beta = (g as f64 + 0.5) / values.len() as f64;
    Contaminated { values, beta, lo, hi }
}

#[test]
fn trimming_removes_non_finite_extremes() {
    forall("non-finite payloads are trimmed away", 200, gen_contaminated, |case, rng| {
        let mut v = case.values.clone();
        for i in (1..v.len()).rev() {
            v.swap(i, rng.below(i + 1));
        }
        let t = trimmed_mean_of(&mut v.clone(), case.beta);
        if !t.is_finite() || !(case.lo..=case.hi).contains(&t) {
            return Err(format!(
                "trimmed mean not stabilized: {t} (finite range [{}, {}])",
                case.lo, case.hi
            ));
        }
        // NaN/-inf/+inf sort to the extremes, so the median's middle
        // stays finite for this contamination level too
        let m = coordinate_median_of(&mut v.clone());
        if !m.is_finite() {
            return Err(format!("median not stabilized: {m}"));
        }
        Ok(())
    });
}
