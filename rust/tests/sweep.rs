//! Sweep-engine integration tests — the PR's acceptance criteria:
//! a `SweepSpec` (and its expansion) round-trips through JSON; a 6-run
//! grid executed with `workers = 1` and `workers = 4` produces
//! byte-identical `sweep.jsonl`; resuming a half-finished sweep dir
//! re-runs only the missing runs.

use std::path::PathBuf;

use cidertf::engine::spec::ExperimentSpec;
use cidertf::engine::AlgoConfig;
use cidertf::losses::Loss;
use cidertf::sweep::{self, SweepOptions, SweepSpec};

fn tiny_base() -> ExperimentSpec {
    let mut base = ExperimentSpec::new("tiny", Loss::Logit, AlgoConfig::cidertf(2));
    base.k = 2;
    base.rank = 4;
    base.fiber_samples = 16;
    base.eval_batch = 64;
    base.gamma = 0.5;
    base.epochs = 1;
    base.iters_per_epoch = 30;
    base.backend = "native".to_string();
    base
}

/// 2 algos × 3 seeds = 6 runs, all sharing one Arc-loaded dataset.
fn six_run_grid() -> SweepSpec {
    let mut spec = SweepSpec::new(tiny_base());
    spec.algos = vec![AlgoConfig::cidertf(2), AlgoConfig::dpsgd()];
    spec.seeds = vec![1, 2, 3];
    spec
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cidertf_sweep_test_{name}"));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn quiet_opts(dir: PathBuf, workers: usize) -> SweepOptions {
    let mut opts = SweepOptions::new(dir, workers);
    opts.quiet = true;
    opts
}

#[test]
fn sweep_spec_and_expansion_round_trip_through_json() {
    let spec = six_run_grid();
    let text = spec.to_json().to_pretty_string();
    let back = SweepSpec::from_json_str(&text).expect("sweep spec parses back");
    assert_eq!(back, spec);
    // the *expansion* survives the round trip too — the resumability and
    // determinism guarantees key on it
    let runs = spec.expand().unwrap();
    let back_runs = back.expand().unwrap();
    assert_eq!(runs.len(), 6);
    assert_eq!(runs, back_runs);
    // every expanded cell itself round-trips (it is a full ExperimentSpec)
    for r in &runs {
        let cell = ExperimentSpec::from_json_str(&r.to_json().to_string()).unwrap();
        assert_eq!(&cell, r);
    }
}

#[test]
fn multi_worker_aggregate_is_bit_identical_to_single_worker() {
    let spec = six_run_grid();

    let dir1 = tmp_dir("workers1");
    let out1 = sweep::execute(&spec, &quiet_opts(dir1.clone(), 1), None).unwrap();
    let jsonl1 = std::fs::read(&out1.jsonl_path).unwrap();

    let dir4 = tmp_dir("workers4");
    let out4 = sweep::execute(&spec, &quiet_opts(dir4.clone(), 4), None).unwrap();
    let jsonl4 = std::fs::read(&out4.jsonl_path).unwrap();

    assert_eq!(out1.results.len(), 6);
    assert_eq!(out4.results.len(), 6);
    assert!(!jsonl1.is_empty());
    assert_eq!(
        jsonl1, jsonl4,
        "sweep.jsonl must be byte-identical for any worker count"
    );
    // 6 runs + header
    assert_eq!(jsonl1.iter().filter(|&&b| b == b'\n').count(), 7);
    // and the per-run records agree on the deterministic fields
    for (a, b) in out1.results.iter().zip(out4.results.iter()) {
        assert_eq!(a.record.final_loss().to_bits(), b.record.final_loss().to_bits());
        assert_eq!(a.record.total.bytes, b.record.total.bytes);
        assert_eq!(a.record.total.messages, b.record.total.messages);
    }

    std::fs::remove_dir_all(&dir1).ok();
    std::fs::remove_dir_all(&dir4).ok();
}

#[test]
fn resume_skips_finished_runs_and_reruns_missing_ones() {
    let spec = six_run_grid();
    let dir = tmp_dir("resume");
    let out = sweep::execute(&spec, &quiet_opts(dir.clone(), 2), None).unwrap();
    assert_eq!(out.skipped(), 0);
    let jsonl_before = std::fs::read(&out.jsonl_path).unwrap();

    // simulate a half-finished sweep: drop two run records
    let mut record_files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| {
            let name = p.file_name().unwrap().to_string_lossy().into_owned();
            name.starts_with("run_") && name.ends_with(".json")
        })
        .collect();
    record_files.sort();
    assert_eq!(record_files.len(), 6, "one record file per run");
    std::fs::remove_file(&record_files[1]).unwrap();
    std::fs::remove_file(&record_files[4]).unwrap();

    let resumed = sweep::execute(&spec, &quiet_opts(dir.clone(), 2), None).unwrap();
    assert_eq!(resumed.skipped(), 4, "only the two missing runs re-execute");
    for (i, r) in resumed.results.iter().enumerate() {
        assert_eq!(r.skipped, i != 1 && i != 4, "run {i}");
    }
    // the aggregate is regenerated and identical (runs are deterministic)
    let jsonl_after = std::fs::read(&resumed.jsonl_path).unwrap();
    assert_eq!(jsonl_before, jsonl_after);

    // a spec drift forces a full re-run: same dir, different seed axis
    let mut drifted = spec.clone();
    drifted.seeds = vec![4, 5, 6];
    let fresh = sweep::execute(&drifted, &quiet_opts(dir.clone(), 2), None).unwrap();
    assert_eq!(fresh.skipped(), 0, "changed specs must not reuse stale records");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn curves_and_records_land_in_the_sweep_dir() {
    let mut spec = SweepSpec::new(tiny_base());
    spec.seeds = vec![9];
    let dir = tmp_dir("outputs");
    let out = sweep::execute(&spec, &quiet_opts(dir.clone(), 1), None).unwrap();
    assert_eq!(out.results.len(), 1);
    let label = out.runs[0].label();
    assert!(dir.join(format!("{label}.csv")).exists(), "per-run curve CSV");
    assert!(dir.join(format!("run_000_{label}.json")).exists(), "per-run record");
    assert!(dir.join("sweep.jsonl").exists(), "aggregate");
    std::fs::remove_dir_all(&dir).ok();
}
