//! Thread-count invariance gate for the persistent worker pool.
//!
//! The pooled gradient (`runtime::native`) and the parallel fiber gather
//! (`tensor::fiber`) promise **bit-identical** results at every thread
//! count: panels are summed in a fixed order regardless of which worker
//! computed them, and gather jobs partition the output so every cell has
//! exactly one writer. This test runs the same experiment through the
//! Session API at 1/2/4/8 compute threads — on a dataset large enough to
//! actually engage both pooled paths — and asserts the factors, the
//! per-epoch losses, and the communication ledger are byte-for-byte
//! equal. A second test switches thread counts *across* a
//! checkpoint/resume boundary.

use cidertf::data::Dataset;
use cidertf::engine::session::Session;
use cidertf::engine::spec::ExperimentSpec;
use cidertf::engine::{AlgoConfig, TrainOutcome};
use cidertf::losses::Loss;
use cidertf::net::driver::DriverKind;
use cidertf::runtime::native::NativeBackend;
use cidertf::runtime::pool::thresholds;
use cidertf::tensor::synth::{SynthConfig, ValueKind};

/// 2400 patient rows split over k=2 clients leaves 1200 rows per client
/// — enough for the mode-0 gradient to fan out to 4 pooled threads
/// (`1200 / GRAD_MIN_ROWS_PER_THREAD = 4`) — and 1200 x 512 sampled
/// fibers is above `GATHER_PAR_MIN_CELLS`, so the slice gather
/// parallelizes too.
fn pooled_scale_data() -> Dataset {
    SynthConfig {
        dims: vec![2400, 64, 64],
        rank: 4,
        support_frac: 0.25,
        fire_prob: 0.5,
        noise_frac: 0.2,
        value_kind: ValueKind::Binary,
        seed: 0xBEEF_0001,
    }
    .generate()
}

fn pooled_scale_spec() -> ExperimentSpec {
    // all-mode steps: every iteration takes a mode-0 step, so the pooled
    // gradient and parallel gather are exercised regardless of the block
    // sampler's draw sequence
    let mut algo = AlgoConfig::cidertf(2);
    algo.block_random = false;
    let spec = ExperimentSpec::builder("synthetic", Loss::Ls, algo)
        .rank(4)
        .fiber_samples(512)
        .k(2)
        .gamma(0.2)
        .iters_per_epoch(3)
        .epochs(2)
        .eval_batch(64)
        .init_scale(0.3)
        .driver(DriverKind::Sim)
        .build()
        .unwrap();
    // sanity: the shape really crosses both engagement thresholds
    let rows_per_client = 2400 / spec.k;
    assert!(rows_per_client >= thresholds::GRAD_PAR_MIN_ROWS);
    assert!(rows_per_client * spec.fiber_samples >= thresholds::GATHER_PAR_MIN_CELLS);
    spec
}

fn run_at_threads(threads: usize, data: &Dataset) -> TrainOutcome {
    let mut spec = pooled_scale_spec();
    spec.compute_threads = threads;
    let mut backend = NativeBackend::new();
    Session::new(spec).run_on(data, &mut backend, None).unwrap()
}

fn assert_outcomes_bit_identical(a: &TrainOutcome, b: &TrainOutcome, what: &str) {
    for (m, (x, y)) in a.factors.mats.iter().zip(b.factors.mats.iter()).enumerate() {
        assert_eq!(x.data, y.data, "{what}: factors diverged (mode {m})");
    }
    assert_eq!(a.record.points.len(), b.record.points.len(), "{what}");
    for (p, q) in a.record.points.iter().zip(b.record.points.iter()) {
        assert_eq!(p.epoch, q.epoch, "{what}");
        assert_eq!(p.loss, q.loss, "{what}: loss diverged at epoch {}", p.epoch);
        assert_eq!(p.bytes, q.bytes, "{what}: comm bytes diverged at epoch {}", p.epoch);
    }
    assert_eq!(a.record.total.bytes, b.record.total.bytes, "{what}");
    assert_eq!(a.record.total.triggered, b.record.total.triggered, "{what}");
    assert_eq!(a.record.net.delivered, b.record.net.delivered, "{what}");
}

#[test]
fn outcomes_bit_identical_at_1_2_4_8_threads() {
    let data = pooled_scale_data();
    let single = run_at_threads(1, &data);
    for threads in [2, 4, 8] {
        let pooled = run_at_threads(threads, &data);
        assert_outcomes_bit_identical(&single, &pooled, &format!("threads={threads}"));
    }
}

#[test]
fn resume_across_a_thread_count_change_is_bit_identical() {
    // a checkpoint written by a 4-thread run and resumed at 8 threads
    // must land exactly where an uninterrupted single-thread run does:
    // thread count is a performance knob, never part of the trajectory
    let data = pooled_scale_data();
    let reference = run_at_threads(1, &data);

    let dir = std::env::temp_dir().join("cidertf_thread_identity_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("switch_{}.ckpt.json", std::process::id()));

    let mut truncated = pooled_scale_spec();
    truncated.epochs = 1;
    truncated.compute_threads = 4;
    let mut backend = NativeBackend::new();
    Session::new(truncated)
        .checkpoint_every(&path, 1)
        .run_on(&data, &mut backend, None)
        .unwrap();

    let mut resumed = Session::resume_from(&path).unwrap();
    resumed.spec_mut().epochs = 2;
    resumed.spec_mut().compute_threads = 8;
    let mut backend = NativeBackend::new();
    let out = resumed.run_on(&data, &mut backend, None).unwrap();
    std::fs::remove_file(&path).ok();

    assert_outcomes_bit_identical(&reference, &out, "4->8 thread resume");
}
