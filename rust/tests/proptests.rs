//! Randomized property tests over the core invariants, using the in-crate
//! `propcheck` substrate (seeded; reproduce single cases with
//! `CIDERTF_PROP_SEED=<seed>`).

use cidertf::compress::{Compressor, Payload};
use cidertf::factor::{fms::fms, FactorSet};
use cidertf::losses::Loss;
use cidertf::runtime::native::NativeBackend;
use cidertf::runtime::ComputeBackend;
use cidertf::tensor::fiber::FiberIndex;
use cidertf::tensor::partition::partition_mode0;
use cidertf::tensor::{encode_fiber, SparseTensor};
use cidertf::topology::{metropolis_weights, Graph, Topology};
use cidertf::util::json::Json;
use cidertf::util::mat::Mat;
use cidertf::util::propcheck::forall;
use cidertf::util::rng::Rng;

fn random_tensor(rng: &mut Rng) -> SparseTensor {
    let d = 3 + rng.below(2); // order 3 or 4
    let dims: Vec<usize> = (0..d).map(|_| 3 + rng.below(8)).collect();
    let mut t = SparseTensor::new(dims.clone());
    let n_cells: usize = dims.iter().product();
    let nnz = 1 + rng.below(n_cells / 2);
    let mut seen = std::collections::HashSet::new();
    for _ in 0..nnz {
        let idx: Vec<u32> = dims.iter().map(|&dm| rng.below(dm) as u32).collect();
        if seen.insert(t.linearize(&idx)) {
            t.push(&idx, rng.normal_f32() + 0.01);
        }
    }
    t
}

#[test]
fn prop_sign_compressor_definition() {
    // decode(Sign(x)) == ||x||_1/n * sign(x) elementwise, and the payload
    // is ~1 bit per entry
    forall(
        "sign-definition",
        50,
        |g| {
            let rows = 1 + g.below(40);
            let cols = 1 + g.below(20);
            Mat::rand_normal(rows, cols, 1.0, g)
        },
        |m, _| {
            let p = Compressor::Sign.compress(m);
            let d = p.decode(m.rows, m.cols);
            let n = m.data.len();
            let scale = (m.l1() / n as f64) as f32;
            for (x, y) in m.data.iter().zip(d.data.iter()) {
                let want = if *x >= 0.0 { scale } else { -scale };
                if (y - want).abs() > 1e-6 {
                    return Err(format!("decode {y} != {want}"));
                }
            }
            let max_bytes = 4 + n.div_ceil(8) as u64;
            if p.wire_bytes() != max_bytes {
                return Err(format!("wire {} != {max_bytes}", p.wire_bytes()));
            }
            Ok(())
        },
    );
}

/// Encode/decode round-trip for every compressor, with matrix sizes
/// deliberately hitting non-multiple-of-8 lengths (Sign bit-packing tail
/// bytes) and single-element edge cases.
#[test]
fn prop_payload_roundtrip_and_wire_bytes() {
    forall(
        "payload-roundtrip",
        60,
        |g| {
            // n in [1, 257], biased toward sizes straddling byte boundaries
            let rows = 1 + g.below(17);
            let cols = 1 + g.below(15);
            let ratio = 2 + g.below(8) as u32;
            (Mat::rand_normal(rows, cols, 1.0, g), ratio)
        },
        |(m, ratio), _| {
            let n = m.data.len();
            for c in [Compressor::None, Compressor::Sign, Compressor::TopK { ratio: *ratio }] {
                let p = c.compress(m);
                // wire_bytes must match the documented encoding exactly
                let want_bytes = match &p {
                    Payload::Dense(v) => 4 * v.len() as u64,
                    Payload::Sign { bits, .. } => 4 + bits.len() as u64,
                    Payload::TopK { indices, values, .. } => {
                        4 * (indices.len() + values.len()) as u64
                    }
                    Payload::Zero { .. } => 0,
                };
                if p.wire_bytes() != want_bytes {
                    return Err(format!("{c:?}: wire {} != {want_bytes}", p.wire_bytes()));
                }
                if let Payload::Sign { bits, len, .. } = &p {
                    if *len != n || bits.len() != n.div_ceil(8) {
                        return Err(format!("sign packing: {} bytes for n={n}", bits.len()));
                    }
                }
                // decode and add_into must agree (add_into on zeros = decode)
                let d = p.decode(m.rows, m.cols);
                let mut z = Mat::zeros(m.rows, m.cols);
                p.add_into(&mut z);
                if d.data != z.data {
                    return Err(format!("{c:?}: decode != add_into-on-zero"));
                }
                // None round-trips exactly
                if matches!(c, Compressor::None) && d.data != m.data {
                    return Err("dense payload not lossless".into());
                }
                // Sign: |value| = ||m||_1/n everywhere, sign preserved
                if matches!(c, Compressor::Sign) {
                    let scale = (m.l1() / n as f64) as f32;
                    for (x, y) in m.data.iter().zip(d.data.iter()) {
                        let want = if *x >= 0.0 { scale } else { -scale };
                        if (y - want).abs() > 1e-6 {
                            return Err(format!("sign decode {y} != {want}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// TopK payloads keep indices strictly in-bounds, sorted, and unique —
/// the invariants the receive-side `add_into` scatter relies on.
#[test]
fn prop_topk_index_bounds_and_uniqueness() {
    forall(
        "topk-indices",
        50,
        |g| {
            let rows = 1 + g.below(12);
            let cols = 1 + g.below(12);
            let ratio = 2 + g.below(12) as u32;
            (Mat::rand_normal(rows, cols, 1.0, g), ratio)
        },
        |(m, ratio), _| {
            let n = m.data.len();
            let p = Compressor::TopK { ratio: *ratio }.compress(m);
            let Payload::TopK { indices, values, len } = &p else {
                return Err("TopK compressor produced a non-TopK payload".into());
            };
            if *len != n {
                return Err(format!("len {len} != {n}"));
            }
            if indices.len() != values.len() {
                return Err("index/value arity mismatch".into());
            }
            let k = (n as u32 / ratio).max(1) as usize;
            if indices.is_empty() || indices.len() > k {
                return Err(format!("kept {} of expected <= {k}", indices.len()));
            }
            for w in indices.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("indices not strictly increasing: {w:?}"));
                }
            }
            if indices.iter().any(|&i| i as usize >= n) {
                return Err("index out of bounds".into());
            }
            for (&i, &v) in indices.iter().zip(values.iter()) {
                if m.data[i as usize] != v {
                    return Err(format!("value at {i} mutated: {v}"));
                }
            }
            Ok(())
        },
    );
}

/// Compressing arbitrary matrices — including empty, single-element, and
/// NaN/±inf-poisoned ones — must never panic for any compressor variant,
/// and `wire_bytes` must match the serialized body size of whatever
/// payload comes back (the uniform body-only convention: Dense `4n`,
/// Sign `4 + ⌈n/8⌉`, TopK `8k`, Zero `0`).
#[test]
fn prop_compress_decode_never_panics_even_with_nan() {
    forall(
        "compressor-nan-robustness",
        80,
        |g| {
            let rows = g.below(10); // 0 is a valid (empty) shape
            let cols = g.below(10);
            let mut m = Mat::rand_normal(rows, cols, 1.0, g);
            let n = m.data.len();
            if n > 0 {
                for _ in 0..g.below(4) {
                    let i = g.below(n);
                    m.data[i] = match g.below(3) {
                        0 => f32::NAN,
                        1 => f32::INFINITY,
                        _ => f32::NEG_INFINITY,
                    };
                }
            }
            let ratio = g.below(10) as u32; // includes degenerate 0 and 1
            (m, ratio)
        },
        |(m, ratio), _| {
            let n = m.data.len();
            for c in [Compressor::None, Compressor::Sign, Compressor::TopK { ratio: *ratio }] {
                let p = c.compress(m); // must not panic
                let want_bytes = match &p {
                    Payload::Dense(v) => 4 * v.len() as u64,
                    Payload::Sign { bits, .. } => 4 + bits.len() as u64,
                    Payload::TopK { indices, values, .. } => {
                        4 * (indices.len() + values.len()) as u64
                    }
                    Payload::Zero { .. } => 0,
                };
                if p.wire_bytes() != want_bytes {
                    return Err(format!("{c:?}: wire {} != {want_bytes}", p.wire_bytes()));
                }
                let d = p.decode(m.rows, m.cols); // must not panic
                if d.data.len() != n {
                    return Err(format!("{c:?}: decode len {} != {n}", d.data.len()));
                }
                let mut t = Mat::zeros(m.rows, m.cols);
                p.add_into(&mut t); // must not panic
                if let Payload::TopK { indices, values, .. } = &p {
                    if indices.len() != values.len() {
                        return Err("TopK arity mismatch".into());
                    }
                    if indices.iter().any(|&i| i as usize >= n) {
                        return Err("TopK index out of bounds".into());
                    }
                }
            }
            Ok(())
        },
    );
}

/// Zero payloads cost nothing on the wire and decode to zeros at every
/// shape — the suppressed-trigger fast path.
#[test]
fn prop_zero_payload_is_free() {
    forall(
        "zero-payload",
        30,
        |g| (1 + g.below(20), 1 + g.below(20)),
        |&(rows, cols), _| {
            let p = Payload::Zero { len: rows * cols };
            if p.wire_bytes() != 0 {
                return Err("zero payload charged bytes".into());
            }
            if p.decode(rows, cols).data.iter().any(|&v| v != 0.0) {
                return Err("zero payload decoded nonzero".into());
            }
            let mut t = Mat::from_fn(rows, cols, |i, j| (i + j) as f32);
            let before = t.clone();
            p.add_into(&mut t);
            if t.data != before.data {
                return Err("zero add_into changed the target".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_topk_decode_is_subset_and_largest() {
    forall(
        "topk-largest",
        40,
        |g| {
            let n = 8 + g.below(64);
            (Mat::rand_normal(1, n, 1.0, g), 2 + (g.below(6) as u32))
        },
        |(m, ratio), _| {
            let p = Compressor::TopK { ratio: *ratio }.compress(m);
            let d = p.decode(1, m.cols);
            let k = (m.cols as u32 / ratio).max(1) as usize;
            let kept: Vec<usize> = (0..m.cols).filter(|&i| d.data[i] != 0.0).collect();
            if kept.len() > k {
                return Err(format!("kept {} > k {k}", kept.len()));
            }
            let min_kept = kept.iter().map(|&i| m.data[i].abs()).fold(f32::INFINITY, f32::min);
            for i in 0..m.cols {
                if d.data[i] == 0.0 && m.data[i].abs() > min_kept + 1e-6 {
                    return Err(format!("dropped larger value at {i}"));
                }
                if d.data[i] != 0.0 && d.data[i] != m.data[i] {
                    return Err("kept value mutated".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fiber_gather_matches_bruteforce() {
    // The CSF-layout index must be bit-identical to a reference COO scan
    // for random tensors — dims (order 3-4, per-mode sizes), density
    // (nnz), and the queried mode are all generated.
    forall(
        "fiber-gather",
        30,
        |g| random_tensor(g),
        |t, check_rng| {
            for mode in 0..t.order() {
                let fi = FiberIndex::build(t, mode);
                let i_dim = t.dims[mode];
                let nf = t.n_fibers(mode);
                let s = 1 + check_rng.below(nf.min(16));
                let fibers: Vec<u64> =
                    check_rng.sample_indices(nf, s).into_iter().map(|x| x as u64).collect();
                let mut out = vec![f32::NAN; i_dim * s];
                fi.gather_slice(&fibers, i_dim, &mut out);
                // brute force: scan all entries
                let mut want = vec![0.0f32; i_dim * s];
                for e in 0..t.nnz() {
                    let fid = encode_fiber(&t.dims, mode, t.entry(e));
                    for (col, &f) in fibers.iter().enumerate() {
                        if f == fid {
                            want[t.entry(e)[mode] as usize * s + col] = t.vals[e];
                        }
                    }
                }
                if out != want {
                    return Err(format!("mode {mode} gather mismatch"));
                }
                // per-fiber accessors agree with the same reference scan
                for &f in &fibers {
                    let mut want_pairs: Vec<(u32, u32)> = (0..t.nnz())
                        .filter(|&e| encode_fiber(&t.dims, mode, t.entry(e)) == f)
                        .map(|e| (t.entry(e)[mode], t.vals[e].to_bits()))
                        .collect();
                    want_pairs.sort_unstable();
                    let mut got_pairs: Vec<(u32, u32)> =
                        fi.fiber_entries(f).map(|(r, v)| (r, v.to_bits())).collect();
                    got_pairs.sort_unstable();
                    if got_pairs != want_pairs {
                        return Err(format!("mode {mode} fiber {f} entries mismatch"));
                    }
                    if fi.fiber_nnz(f) != want_pairs.len() {
                        return Err(format!("mode {mode} fiber {f} nnz mismatch"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fiber_gather_sorted_layout_matches_bruteforce() {
    // Same invariant with mode sizes large enough that the fiber-id space
    // exceeds the dense-offsets cap, forcing the binary-searched layout.
    forall(
        "fiber-gather-sorted",
        10,
        |g| {
            let dims = vec![2 + g.below(4), 1500 + g.below(2000), 1500 + g.below(2000)];
            let mut t = SparseTensor::new(dims.clone());
            let nnz = 5 + g.below(60);
            let mut seen = std::collections::HashSet::new();
            for _ in 0..nnz {
                let idx: Vec<u32> = dims.iter().map(|&dm| g.below(dm) as u32).collect();
                if seen.insert(t.linearize(&idx)) {
                    t.push(&idx, g.normal_f32() + 0.01);
                }
            }
            t
        },
        |t, check_rng| {
            let fi = FiberIndex::build(t, 0);
            if fi.is_dense() {
                return Err("expected the sorted layout for a huge, sparse fiber-id space".into());
            }
            let i_dim = t.dims[0];
            // query a mix of occupied and empty fibers
            let mut fibers: Vec<u64> =
                (0..t.nnz().min(8)).map(|e| encode_fiber(&t.dims, 0, t.entry(e))).collect();
            for _ in 0..4 {
                fibers.push(check_rng.below(t.n_fibers(0)) as u64);
            }
            let s = fibers.len();
            let mut out = vec![f32::NAN; i_dim * s];
            fi.gather_slice(&fibers, i_dim, &mut out);
            let mut want = vec![0.0f32; i_dim * s];
            for e in 0..t.nnz() {
                let fid = encode_fiber(&t.dims, 0, t.entry(e));
                for (col, &f) in fibers.iter().enumerate() {
                    if f == fid {
                        want[t.entry(e)[0] as usize * s + col] = t.vals[e];
                    }
                }
            }
            if out != want {
                return Err("sorted-layout gather mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_partition_preserves_everything() {
    forall(
        "partition-conservation",
        30,
        |g| {
            let t = random_tensor(g);
            let k = 1 + g.below(t.dims[0]);
            (t, k)
        },
        |(t, k), _| {
            let shards = partition_mode0(t, *k);
            let total_nnz: usize = shards.iter().map(|s| s.tensor.nnz()).sum();
            if total_nnz != t.nnz() {
                return Err(format!("nnz {total_nnz} != {}", t.nnz()));
            }
            let total_rows: usize = shards.iter().map(|s| s.tensor.dims[0]).sum();
            if total_rows != t.dims[0] {
                return Err("row count mismatch".into());
            }
            // rows balanced within 1
            let min = shards.iter().map(|s| s.tensor.dims[0]).min().unwrap();
            let max = shards.iter().map(|s| s.tensor.dims[0]).max().unwrap();
            if max - min > 1 {
                return Err(format!("imbalanced shards {min}..{max}"));
            }
            // value multiset preserved per global cell
            let mut global: Vec<(u64, u32)> = Vec::new();
            for sh in &shards {
                for e in 0..sh.tensor.nnz() {
                    let mut idx = sh.tensor.entry(e).to_vec();
                    idx[0] += sh.row_offset as u32;
                    global.push((t.linearize(&idx), sh.tensor.vals[e].to_bits()));
                }
            }
            global.sort_unstable();
            let mut want: Vec<(u64, u32)> =
                (0..t.nnz()).map(|e| (t.linearize(t.entry(e)), t.vals[e].to_bits())).collect();
            want.sort_unstable();
            if global != want {
                return Err("entry multiset changed".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_metropolis_weights_doubly_stochastic() {
    forall(
        "metropolis-doubly-stochastic",
        40,
        |g| {
            let choice = g.below(5);
            let n = match choice {
                4 => {
                    let side = 2 + g.below(4);
                    side * side
                }
                _ => 1 + g.below(32),
            };
            (choice, n)
        },
        |&(choice, n), _| {
            let topo = [Topology::Ring, Topology::Star, Topology::Complete, Topology::Chain, Topology::Torus]
                [choice];
            let g = Graph::build(topo, n).map_err(|e| e.to_string())?;
            for k in 0..n {
                let row: f64 = g.weights[k].iter().sum();
                if (row - 1.0).abs() > 1e-9 {
                    return Err(format!("row {k} sums {row}"));
                }
                for j in 0..n {
                    if (g.weights[k][j] - g.weights[j][k]).abs() > 1e-12 {
                        return Err("asymmetric".into());
                    }
                    if g.weights[k][j] < 0.0 {
                        return Err("negative weight".into());
                    }
                }
            }
            let _ = metropolis_weights(&g.neighbors);
            Ok(())
        },
    );
}

#[test]
fn prop_fms_permutation_and_sign_invariances() {
    forall(
        "fms-permutation",
        25,
        |g| {
            let r = 2 + g.below(6);
            let dims: Vec<usize> = (0..3).map(|_| 5 + g.below(20)).collect();
            let f = FactorSet {
                mats: dims.iter().map(|&d| Mat::rand_normal(d, r, 1.0, g)).collect(),
            };
            let mut perm: Vec<usize> = (0..r).collect();
            g.shuffle(&mut perm);
            (f, perm)
        },
        |(f, perm), _| {
            let permuted = FactorSet {
                mats: f
                    .mats
                    .iter()
                    .map(|m| Mat::from_fn(m.rows, m.cols, |i, j| m.at(i, perm[j])))
                    .collect(),
            };
            let s = fms(f, &permuted);
            if (s - 1.0).abs() > 1e-5 {
                return Err(format!("permuted fms {s}"));
            }
            // global sign flip in one mode is forgiven
            let flipped = FactorSet {
                mats: f
                    .mats
                    .iter()
                    .enumerate()
                    .map(|(k, m)| {
                        let sgn = if k == 0 { -1.0 } else { 1.0 };
                        Mat::from_fn(m.rows, m.cols, |i, j| sgn * m.at(i, j))
                    })
                    .collect(),
            };
            let s = fms(f, &flipped);
            if (s - 1.0).abs() > 1e-5 {
                return Err(format!("flipped fms {s}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(g: &mut Rng, depth: usize) -> Json {
        match if depth > 2 { g.below(4) } else { g.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(g.bernoulli(0.5)),
            2 => Json::Num((g.normal() * 1e3).round() / 8.0),
            3 => {
                let n = g.below(8);
                Json::Str((0..n).map(|_| char::from(32 + g.below(90) as u8)).collect())
            }
            4 => Json::Arr((0..g.below(4)).map(|_| random_json(g, depth + 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..g.below(4) {
                    m.insert(format!("k{i}"), random_json(g, depth + 1));
                }
                Json::Obj(m)
            }
        }
    }
    forall(
        "json-roundtrip",
        60,
        |g| random_json(g, 0),
        |j, _| {
            for text in [j.to_string(), j.to_pretty_string()] {
                let back = Json::parse(&text).map_err(|e| e.to_string())?;
                if &back != j {
                    return Err(format!("roundtrip changed: {text}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_native_grad_is_finite_and_linear_in_scale() {
    forall(
        "grad-scale-linearity",
        25,
        |g| {
            let i = 2 + g.below(20);
            let s = 2 + g.below(16);
            let r = 1 + g.below(8);
            let xs: Vec<f32> = (0..i * s).map(|_| g.normal_f32() * 0.5).collect();
            let a = Mat::rand_normal(i, r, 0.5, g);
            let u1 = Mat::rand_normal(s, r, 0.5, g);
            let u2 = Mat::rand_normal(s, r, 0.5, g);
            (i, s, xs, a, u1, u2)
        },
        |(i, s, xs, a, u1, u2), _| {
            let mut be = NativeBackend::new();
            for loss in [Loss::Ls, Loss::Logit] {
                let (g1, l1) = be.grad(loss, xs, *i, *s, a, &[u1, u2], 1.0).unwrap();
                let (g2, l2) = be.grad(loss, xs, *i, *s, a, &[u1, u2], -2.0).unwrap();
                if !g1.data.iter().all(|v| v.is_finite()) {
                    return Err("non-finite gradient".into());
                }
                if (l1 - l2).abs() > 1e-6 * l1.abs().max(1.0) {
                    return Err("loss depends on scale".into());
                }
                for (x, y) in g1.data.iter().zip(g2.data.iter()) {
                    if (-2.0 * x - y).abs() > 1e-3 * x.abs().max(1e-3) {
                        return Err(format!("not linear in scale: {x} vs {y}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lambda_weights_scale_covariance() {
    // scaling one mode's column c by alpha scales lambda_c by |alpha|
    forall(
        "lambda-covariance",
        25,
        |g| {
            let r = 2 + g.below(5);
            let f = FactorSet {
                mats: (0..3).map(|_| Mat::rand_normal(4 + g.below(10), r, 1.0, g)).collect(),
            };
            let col = g.below(r);
            let alpha = 0.5 + g.uniform() * 4.0;
            (f, col, alpha)
        },
        |(f, col, alpha), _| {
            let before = f.lambda_weights();
            let mut scaled = f.clone();
            for i in 0..scaled.mats[0].rows {
                *scaled.mats[0].at_mut(i, *col) *= *alpha as f32;
            }
            let after = scaled.lambda_weights();
            let want = before[*col] * *alpha;
            if (after[*col] - want).abs() > 1e-3 * want.abs().max(1e-6) {
                return Err(format!("lambda {} != {want}", after[*col]));
            }
            Ok(())
        },
    );
}
