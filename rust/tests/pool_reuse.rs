//! Worker-pool lifecycle gate: sequential sessions reuse the same
//! persistent workers instead of spawning fresh threads per run.
//!
//! Exactly ONE `#[test]` lives in this file on purpose: the assertion
//! reads the process-wide thread count from `/proc/self/status`, and a
//! concurrently running harness test would perturb it.

use cidertf::data::Dataset;
use cidertf::engine::session::Session;
use cidertf::engine::spec::ExperimentSpec;
use cidertf::engine::AlgoConfig;
use cidertf::losses::Loss;
use cidertf::net::driver::DriverKind;
use cidertf::runtime::native::NativeBackend;
use cidertf::runtime::pool;
use cidertf::tensor::synth::{SynthConfig, ValueKind};

/// Kernel-thread count of this process, from `/proc/self/status`
/// (`None` off Linux or if the file is unreadable — the test then skips
/// the OS-level check and keeps the pool-level one).
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("Threads:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

fn run_once(data: &Dataset) {
    // all-mode steps so every iteration hits the pooled mode-0 gradient,
    // independent of the block sampler's draw sequence
    let mut algo = AlgoConfig::cidertf(2);
    algo.block_random = false;
    let mut spec = ExperimentSpec::builder("synthetic", Loss::Ls, algo)
        .rank(4)
        .fiber_samples(64)
        .k(2)
        .gamma(0.2)
        .iters_per_epoch(4)
        .epochs(1)
        .eval_batch(64)
        .init_scale(0.3)
        .driver(DriverKind::Sim)
        .build()
        .unwrap();
    spec.compute_threads = 4;
    let mut backend = NativeBackend::new();
    let out = Session::new(spec).run_on(data, &mut backend, None).unwrap();
    assert!(out.record.final_loss().is_finite());
}

#[test]
fn sequential_sessions_reuse_pool_workers_without_leaking_threads() {
    // 1200 patient rows per client: `1200 / GRAD_MIN_ROWS_PER_THREAD = 4`,
    // so the 4-thread runs fan the gradient out over four pooled jobs and
    // the pool grows to its full three workers (the caller is the fourth)
    let data = SynthConfig {
        dims: vec![2400, 64, 64],
        rank: 4,
        support_frac: 0.25,
        fire_prob: 0.5,
        noise_frac: 0.2,
        value_kind: ValueKind::Binary,
        seed: 0xBEEF_0002,
    }
    .generate();

    // warm run: the pool lazily spawns its workers here
    run_once(&data);
    let workers = pool::worker_count();
    assert!(workers >= 3, "4-thread run left only {workers} pool worker(s)");
    let os_threads = process_threads();

    // every further session must ride the same workers — same pool
    // count, same OS thread count, no per-run spawns
    for run in 0..3 {
        run_once(&data);
        assert_eq!(
            pool::worker_count(),
            workers,
            "pool grew or shrank on sequential run {run}"
        );
        if let (Some(before), Some(now)) = (os_threads, process_threads()) {
            assert_eq!(now, before, "process thread count changed on run {run}");
        }
    }
}
