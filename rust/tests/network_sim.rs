//! Network-simulator integration tests: the ideal-network equivalence
//! guarantee, fault-tolerance envelopes, and async-gossip behaviour — all
//! on the tiny dataset with the native backend (no artifacts needed).

use cidertf::engine::{train, AlgoConfig, TrainConfig};
use cidertf::losses::Loss;
use cidertf::net::async_gossip::train_async;
use cidertf::net::driver::{train_sim, AsyncGossipDriver, RoundDriver, SequentialDriver, SimDriver};
use cidertf::net::sim::{self, FaultConfig, IdealNetwork};
use cidertf::runtime::native::NativeBackend;
use cidertf::runtime::ComputeBackend;
use cidertf::tensor::synth::SynthConfig;
use cidertf::topology::Topology;

fn tiny_cfg(algo: AlgoConfig, k: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new("tiny", Loss::Logit, algo);
    cfg.rank = 4;
    cfg.fiber_samples = 16;
    cfg.k = k;
    cfg.gamma = 0.5;
    cfg.iters_per_epoch = 100;
    cfg.epochs = 6;
    cfg.eval_batch = 64;
    cfg.init_scale = 0.3;
    cfg
}

/// Acceptance criterion: with the ideal network the simulator produces
/// bit-identical factors to `engine::train` for the same seed.
#[test]
fn ideal_sim_is_bit_identical_to_engine() {
    let data = SynthConfig::tiny(42).generate();
    let cfg = tiny_cfg(AlgoConfig::cidertf(2), 4);
    let mut b1 = NativeBackend::new();
    let mut b2 = NativeBackend::new();
    let seq = train(&cfg, &data, &mut b1, None).unwrap();
    let mut net = IdealNetwork;
    let sim = train_sim(&cfg, &data, &mut b2, &mut net, None).unwrap();
    for (a, b) in seq.factors.mats.iter().zip(sim.factors.mats.iter()) {
        assert_eq!(a.data, b.data, "ideal-network sim diverged from engine");
    }
    assert_eq!(seq.record.total.bytes, sim.record.total.bytes);
    assert_eq!(seq.record.total.triggered, sim.record.total.triggered);
    assert_eq!(seq.record.total.suppressed, sim.record.total.suppressed);
    assert_eq!(seq.record.net.delivered, sim.record.net.delivered);
    assert_eq!(sim.record.net.dropped, 0);
    for (p, q) in seq.record.points.iter().zip(sim.record.points.iter()) {
        assert_eq!(p.loss, q.loss, "losses diverged at epoch {}", p.epoch);
    }
}

/// Bit-identity holds for every algorithm family (all-mode, momentum, EF).
#[test]
fn ideal_sim_matches_engine_across_presets() {
    let data = SynthConfig::tiny(7).generate();
    for (algo, k) in [
        (AlgoConfig::dpsgd_sign(), 3),
        (AlgoConfig::cidertf_m(2), 4),
        (AlgoConfig::bras_cpd(), 1),
    ] {
        let name = algo.name.clone();
        let mut cfg = tiny_cfg(algo, k);
        cfg.epochs = 2;
        let mut b1 = NativeBackend::new();
        let mut b2 = NativeBackend::new();
        let seq = train(&cfg, &data, &mut b1, None).unwrap();
        let sim = train_sim(&cfg, &data, &mut b2, &mut IdealNetwork, None).unwrap();
        for (a, b) in seq.factors.mats.iter().zip(sim.factors.mats.iter()) {
            assert_eq!(a.data, b.data, "{name} diverged under ideal sim");
        }
    }
}

/// Acceptance criterion: ≥20% drop on a ring with the Sign compressor
/// still converges to within 2x of the ideal-network final loss, and the
/// record reports delivered/dropped counts.
#[test]
fn lossy_ring_sign_converges_within_2x_of_ideal() {
    let data = SynthConfig::tiny(42).generate();
    let mut cfg = tiny_cfg(AlgoConfig::cidertf(2), 4);
    cfg.topology = Topology::Ring;

    let mut b = NativeBackend::new();
    let ideal = train_sim(&cfg, &data, &mut b, &mut IdealNetwork, None).unwrap();

    let mut lossy_net = FaultConfig::lossy(0.2).with_seed(cfg.seed).build();
    let mut b = NativeBackend::new();
    let lossy = train_sim(&cfg, &data, &mut b, &mut lossy_net, None).unwrap();

    let first = lossy.record.points.first().unwrap().loss;
    let last = lossy.record.final_loss();
    assert!(last.is_finite(), "lossy run diverged: {last}");
    assert!(last < 0.8 * first, "lossy run failed to converge: {first} -> {last}");
    assert!(
        last <= 2.0 * ideal.record.final_loss(),
        "lossy final loss {last} more than 2x ideal {}",
        ideal.record.final_loss()
    );
    // ledger/record accounting
    assert!(lossy.record.net.delivered > 0, "no deliveries recorded");
    assert!(lossy.record.net.dropped > 0, "no drops recorded at 20% loss");
    let frac = lossy.record.net.drop_fraction();
    assert!((frac - 0.2).abs() < 0.08, "observed drop fraction {frac} far from 0.2");
    // uplink is charged at the sender, so bytes stay on the same order as
    // the ideal run even when the network eats 20% of the messages
    assert!(lossy.record.total.bytes > 0);
}

#[test]
fn async_ideal_is_deterministic_and_converges() {
    let data = SynthConfig::tiny(42).generate();
    let cfg = tiny_cfg(AlgoConfig::cidertf(2), 4);
    let mut b1 = NativeBackend::new();
    let mut b2 = NativeBackend::new();
    let o1 = train_async(&cfg, &data, &mut b1, &mut IdealNetwork, None).unwrap();
    let o2 = train_async(&cfg, &data, &mut b2, &mut IdealNetwork, None).unwrap();
    for (a, b) in o1.factors.mats.iter().zip(o2.factors.mats.iter()) {
        assert_eq!(a.data, b.data, "async run is nondeterministic");
    }
    let first = o1.record.points.first().unwrap().loss;
    let last = o1.record.final_loss();
    assert!(last < 0.8 * first, "async did not converge: {first} -> {last}");
    assert!(o1.record.total.bytes > 0);
    assert!(o1.record.net.delivered > 0);
    // an ideal network never loses a message — end-of-run in-flight
    // arrivals are discarded, not charged as drops
    assert_eq!(o1.record.net.dropped, 0, "ideal async reported packet loss");
}

#[test]
fn async_stragglers_stretch_virtual_time_not_correctness() {
    let data = SynthConfig::tiny(42).generate();
    let cfg = tiny_cfg(AlgoConfig::cidertf(2), 4);
    let mut b = NativeBackend::new();
    let ideal = train_async(&cfg, &data, &mut b, &mut IdealNetwork, None).unwrap();
    let mut slow_net =
        FaultConfig { straggler_ids: vec![0], straggler_slow: 4.0, ..Default::default() }.build();
    let mut b = NativeBackend::new();
    let slow = train_async(&cfg, &data, &mut b, &mut slow_net, None).unwrap();
    assert!(
        slow.record.wall_s > ideal.record.wall_s,
        "stragglers did not stretch virtual time: {} vs {}",
        slow.record.wall_s,
        ideal.record.wall_s
    );
    let first = slow.record.points.first().unwrap().loss;
    assert!(slow.record.final_loss() < 0.8 * first, "straggler run failed to converge");
    // under asynchrony, slow publishers produce stale deliveries
    assert!(slow.record.net.stale > 0, "no staleness recorded with stragglers");
}

#[test]
fn churn_is_survivable_and_accounted() {
    let data = SynthConfig::tiny(42).generate();
    let mut cfg = tiny_cfg(AlgoConfig::cidertf(2), 4);
    cfg.epochs = 4;
    let churny = FaultConfig { churn_rate: 0.3, churn_period: 50, ..Default::default() };
    let mut net = churny.with_seed(11).build();
    let mut b = NativeBackend::new();
    let out = train_sim(&cfg, &data, &mut b, &mut net, None).unwrap();
    assert!(out.record.final_loss().is_finite());
    assert!(out.record.net.offline_rounds > 0, "churn never took a client offline");
    let first = out.record.points.first().unwrap().loss;
    assert!(out.record.final_loss() < first, "churned run made no progress");
}

#[test]
fn sim_virtual_clock_reflects_stragglers() {
    let data = SynthConfig::tiny(42).generate();
    let mut cfg = tiny_cfg(AlgoConfig::cidertf(2), 4);
    cfg.epochs = 2;
    let mut b = NativeBackend::new();
    let ideal = train_sim(&cfg, &data, &mut b, &mut IdealNetwork, None).unwrap();
    let mut slow_net =
        FaultConfig { straggler_ids: vec![0], straggler_slow: 4.0, ..Default::default() }.build();
    let mut b = NativeBackend::new();
    let slow = train_sim(&cfg, &data, &mut b, &mut slow_net, None).unwrap();
    // sync barriers wait for the slowest client: the whole run stretches
    // by the straggler multiplier; factors are unaffected (no drops)
    assert!(slow.record.wall_s > 1.5 * ideal.record.wall_s);
    for (a, b) in ideal.factors.mats.iter().zip(slow.factors.mats.iter()) {
        assert_eq!(a.data, b.data, "stragglers alone must not change sync results");
    }
}

#[test]
fn round_drivers_share_one_interface() {
    let data = SynthConfig::tiny(5).generate();
    let mut cfg = tiny_cfg(AlgoConfig::cidertf(2), 4);
    cfg.epochs = 1;
    let mut drivers: Vec<Box<dyn RoundDriver>> = vec![
        Box::new(SequentialDriver { backend: Box::new(NativeBackend::new()) }),
        Box::new(SimDriver { backend: Box::new(NativeBackend::new()), net: sim::ideal() }),
        Box::new(AsyncGossipDriver {
            backend: Box::new(NativeBackend::new()),
            net: FaultConfig::lossy(0.1).boxed(),
        }),
    ];
    for d in drivers.iter_mut() {
        let out = d.run(&cfg, &data, None).unwrap();
        assert!(out.record.final_loss().is_finite(), "driver {} diverged", d.name());
        assert_eq!(out.record.k, 4);
    }
}

/// Higher drop rates hurt monotonically-ish: 40% loss must still not
/// diverge, and must deliver fewer messages than 10% loss.
#[test]
fn drop_rate_scales_delivery_counts() {
    let data = SynthConfig::tiny(42).generate();
    let mut cfg = tiny_cfg(AlgoConfig::cidertf(2), 4);
    cfg.epochs = 2;
    let run = |p: f64| {
        let mut net = FaultConfig::lossy(p).with_seed(cfg.seed).build();
        let mut b = NativeBackend::new();
        train_sim(&cfg, &data, &mut b, &mut net, None).unwrap()
    };
    let light = run(0.1);
    let heavy = run(0.4);
    assert!(heavy.record.net.delivered < light.record.net.delivered);
    assert!(heavy.record.net.dropped > light.record.net.dropped);
    assert!(heavy.record.final_loss().is_finite());
}

#[test]
fn parallel_backend_trait_object_still_works() {
    // regression guard for the driver refactor: the dyn-compatible
    // ComputeBackend boxing used by driver_from_flags
    let backend: Box<dyn ComputeBackend> = Box::new(NativeBackend::new());
    let mut d = SequentialDriver { backend };
    let data = SynthConfig::tiny(9).generate();
    let mut cfg = tiny_cfg(AlgoConfig::bras_cpd(), 1);
    cfg.epochs = 1;
    let out = d.run(&cfg, &data, None).unwrap();
    assert_eq!(out.record.total.bytes, 0);
}
