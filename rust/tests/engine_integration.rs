//! End-to-end engine tests on the tiny dataset with the native backend:
//! convergence, determinism, communication accounting, consensus.

use cidertf::engine::{train, AlgoConfig, TrainConfig};
use cidertf::losses::Loss;
use cidertf::runtime::native::NativeBackend;
use cidertf::tensor::synth::{SynthConfig, ValueKind};
use cidertf::topology::Topology;

fn tiny_cfg(algo: AlgoConfig, loss: Loss, k: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new("tiny", loss, algo);
    cfg.rank = 4;
    cfg.fiber_samples = 16;
    cfg.k = k;
    cfg.gamma = 0.5;
    cfg.iters_per_epoch = 100;
    cfg.epochs = 6;
    cfg.eval_batch = 64;
    cfg.init_scale = 0.3;
    cfg
}

fn tiny_data(loss: Loss) -> cidertf::tensor::synth::SynthData {
    let vk = if loss == Loss::Ls { ValueKind::Gaussian } else { ValueKind::Binary };
    SynthConfig::tiny(42).with_values(vk).generate()
}

#[test]
fn cidertf_converges_decentralized_logit() {
    let data = tiny_data(Loss::Logit);
    let cfg = tiny_cfg(AlgoConfig::cidertf(4), Loss::Logit, 4);
    let mut backend = NativeBackend::new();
    let out = train(&cfg, &data, &mut backend, None).unwrap();
    let first = out.record.points.first().unwrap().loss;
    let last = out.record.final_loss();
    assert!(last < 0.7 * first, "no convergence: {first} -> {last}");
    assert!(out.record.total.bytes > 0, "no communication recorded");
}

#[test]
fn cidertf_converges_decentralized_ls() {
    let data = tiny_data(Loss::Ls);
    let mut cfg = tiny_cfg(AlgoConfig::cidertf(2), Loss::Ls, 4);
    cfg.gamma = 0.5;
    cfg.epochs = 12;
    let mut backend = NativeBackend::new();
    let out = train(&cfg, &data, &mut backend, None).unwrap();
    let first = out.record.points.first().unwrap().loss;
    let last = out.record.final_loss();
    assert!(last < 0.9 * first, "no convergence: {first} -> {last}");
    assert!(last.is_finite());
}

#[test]
fn compute_threads_do_not_change_training() {
    // the lane-deterministic blocked kernels keep the gradient — and
    // therefore the whole training trajectory — bit-identical whether the
    // row-panel loop runs on 1 thread or several. The patient mode needs
    // i_dim >= 2*MIN_ROWS_PER_THREAD (2048) for the scoped pool to
    // actually engage (tiny's 64 rows would silently fall back to the
    // single-thread path), so this test plants a taller tensor.
    let data = SynthConfig {
        dims: vec![2304, 8, 8],
        rank: 4,
        support_frac: 0.3,
        fire_prob: 0.5,
        noise_frac: 0.2,
        value_kind: ValueKind::Binary,
        seed: 31,
    }
    .generate();
    let mut cfg1 = tiny_cfg(AlgoConfig::cidertf(4), Loss::Logit, 1);
    cfg1.iters_per_epoch = 30;
    cfg1.epochs = 2;
    let mut cfg4 = cfg1.clone();
    cfg4.compute_threads = 4;
    let mut b1 = NativeBackend::new();
    let mut b4 = NativeBackend::new();
    let o1 = train(&cfg1, &data, &mut b1, None).unwrap();
    let o4 = train(&cfg4, &data, &mut b4, None).unwrap();
    for (a, b) in o1.factors.mats.iter().zip(o4.factors.mats.iter()) {
        assert_eq!(a.data, b.data, "thread count changed the factors");
    }
    assert_eq!(o1.record.total.bytes, o4.record.total.bytes);
}

#[test]
fn training_is_deterministic() {
    let data = tiny_data(Loss::Logit);
    let cfg = tiny_cfg(AlgoConfig::cidertf(4), Loss::Logit, 4);
    let mut b1 = NativeBackend::new();
    let mut b2 = NativeBackend::new();
    let o1 = train(&cfg, &data, &mut b1, None).unwrap();
    let o2 = train(&cfg, &data, &mut b2, None).unwrap();
    for (p1, p2) in o1.record.points.iter().zip(o2.record.points.iter()) {
        assert_eq!(p1.loss, p2.loss);
        assert_eq!(p1.bytes, p2.bytes);
    }
    for (m1, m2) in o1.factors.mats.iter().zip(o2.factors.mats.iter()) {
        assert_eq!(m1.data, m2.data);
    }
}

#[test]
fn centralized_baselines_run_without_comm() {
    let data = tiny_data(Loss::Logit);
    for algo in [AlgoConfig::gcp(), AlgoConfig::bras_cpd(), AlgoConfig::centralized_cidertf()] {
        let name = algo.name.clone();
        let mut cfg = tiny_cfg(algo, Loss::Logit, 1);
        cfg.epochs = 4;
        let mut backend = NativeBackend::new();
        let out = train(&cfg, &data, &mut backend, None).unwrap();
        assert_eq!(out.record.total.bytes, 0, "{name}: centralized run communicated");
        let first = out.record.points.first().unwrap().loss;
        assert!(
            out.record.final_loss() < first,
            "{name}: loss went up: {first} -> {}",
            out.record.final_loss()
        );
    }
}

#[test]
fn comm_cost_ordering_matches_paper() {
    // D-PSGD >> D-PSGDbras (x~D) >> sign variants (x~32) >> CiderTF
    let data = tiny_data(Loss::Logit);
    let mut bytes = std::collections::BTreeMap::new();
    for algo in [
        AlgoConfig::dpsgd(),
        AlgoConfig::dpsgd_bras(),
        AlgoConfig::dpsgd_sign(),
        AlgoConfig::dpsgd_bras_sign(),
        AlgoConfig::sparq_sgd(4),
        AlgoConfig::cidertf(4),
    ] {
        let name = algo.name.clone();
        let mut cfg = tiny_cfg(algo, Loss::Logit, 4);
        cfg.epochs = 2;
        let mut backend = NativeBackend::new();
        let out = train(&cfg, &data, &mut backend, None).unwrap();
        bytes.insert(name, out.record.total.bytes);
    }
    assert!(bytes["dpsgd"] > bytes["dpsgd_bras"]);
    assert!(bytes["dpsgd"] > bytes["dpsgd_sign"]);
    assert!(bytes["dpsgd_sign"] > bytes["dpsgd_bras_sign"]);
    assert!(bytes["dpsgd_bras_sign"] > bytes["cidertf_t4"]);
    assert!(bytes["sparq_sgd_t4"] > bytes["cidertf_t4"]);
    // headline: sign+block+periodic+event cuts D-PSGD bytes by >99%
    let reduction = 1.0 - bytes["cidertf_t4"] as f64 / bytes["dpsgd"] as f64;
    assert!(reduction > 0.99, "reduction only {reduction}");
}

#[test]
fn topology_affects_bytes_not_convergence() {
    let data = tiny_data(Loss::Logit);
    let mut results = Vec::new();
    for topo in [Topology::Ring, Topology::Star] {
        let mut cfg = tiny_cfg(AlgoConfig::cidertf(2), Loss::Logit, 4);
        cfg.topology = topo;
        let mut backend = NativeBackend::new();
        let out = train(&cfg, &data, &mut backend, None).unwrap();
        results.push((topo, out.record.total.bytes, out.record.final_loss()));
    }
    let (_, ring_bytes, ring_loss) = results[0];
    let (_, star_bytes, star_loss) = results[1];
    // star has fewer total links -> fewer uplink bytes (paper Fig. 4)
    assert!(star_bytes < ring_bytes, "star {star_bytes} vs ring {ring_bytes}");
    // both converge to the same ballpark
    let rel = (ring_loss - star_loss).abs() / ring_loss.max(star_loss);
    assert!(rel < 0.25, "topologies diverged: ring {ring_loss} star {star_loss}");
}

#[test]
fn event_trigger_suppresses_late_in_training() {
    let data = tiny_data(Loss::Logit);
    let mut cfg = tiny_cfg(AlgoConfig::cidertf(2), Loss::Logit, 4);
    cfg.epochs = 8;
    let mut backend = NativeBackend::new();
    let out = train(&cfg, &data, &mut backend, None).unwrap();
    assert!(
        out.record.total.suppressed > 0,
        "event trigger never suppressed a round (triggered {})",
        out.record.total.triggered
    );
    assert!(out.record.total.triggered > 0, "event trigger never fired");
}

#[test]
fn momentum_converges_faster_at_same_gamma() {
    // Nesterov momentum amplifies the effective step (~1/(1-beta)); at a
    // small shared gamma the momentum run must converge much further
    // (paper Fig. 3 observation iv).
    let data = tiny_data(Loss::Logit);
    let mut backend = NativeBackend::new();
    let mut cfg_plain = tiny_cfg(AlgoConfig::cidertf(4), Loss::Logit, 4);
    cfg_plain.gamma = 0.05;
    cfg_plain.epochs = 8;
    let mut cfg_mom = tiny_cfg(AlgoConfig::cidertf_m(4), Loss::Logit, 4);
    cfg_mom.gamma = 0.05;
    cfg_mom.epochs = 8;
    let plain = train(&cfg_plain, &data, &mut backend, None).unwrap();
    let mom = train(&cfg_mom, &data, &mut backend, None).unwrap();
    assert!(
        mom.record.final_loss() < 0.5 * plain.record.final_loss(),
        "momentum not faster: {} vs {}",
        mom.record.final_loss(),
        plain.record.final_loss()
    );
}

#[test]
fn fms_vs_centralized_baseline_is_high() {
    // Paper Fig. 7: FMS compares decentralized factors against the
    // *centralized BrasCPD* factors (not ground truth) — converged runs
    // land in matching basins.
    let data = tiny_data(Loss::Logit);
    let mut backend = NativeBackend::new();
    let mut cfg_b = tiny_cfg(AlgoConfig::bras_cpd(), Loss::Logit, 1);
    cfg_b.epochs = 25;
    let bras = train(&cfg_b, &data, &mut backend, None).unwrap();
    let mut cfg_c = tiny_cfg(AlgoConfig::cidertf(2), Loss::Logit, 4);
    cfg_c.epochs = 25;
    let cider = train(&cfg_c, &data, &mut backend, None).unwrap();
    let score = cidertf::factor::fms::fms(&cider.factors, &bras.factors);
    // an untrained factor set scores low against the converged baseline
    let init = cidertf::factor::FactorSet::init_uniform(&data.tensor.dims, 4, 0.3, 9);
    let base = cidertf::factor::fms::fms(&init, &bras.factors);
    assert!(score > 0.4, "fms(cider, bras) = {score}");
    assert!(score > base, "converged fms {score} <= untrained {base}");
}

#[test]
fn assemble_global_shapes() {
    let data = tiny_data(Loss::Logit);
    let cfg = tiny_cfg(AlgoConfig::cidertf(4), Loss::Logit, 4);
    let mut backend = NativeBackend::new();
    let out = train(&cfg, &data, &mut backend, None).unwrap();
    assert_eq!(out.factors.mats[0].rows, data.tensor.dims[0]);
    for m in 1..3 {
        assert_eq!(out.factors.mats[m].rows, data.tensor.dims[m]);
    }
}

#[test]
fn scalability_k_sweep_converges() {
    let data = tiny_data(Loss::Logit);
    for k in [2usize, 4, 8] {
        let mut cfg = tiny_cfg(AlgoConfig::cidertf(4), Loss::Logit, k);
        cfg.epochs = 5;
        let mut backend = NativeBackend::new();
        let out = train(&cfg, &data, &mut backend, None).unwrap();
        let first = out.record.points.first().unwrap().loss;
        assert!(out.record.final_loss() < first, "k={k} did not improve");
    }
}
