//! Preset-level integration tests: every algorithm runs, and the measured
//! per-epoch communication matches each preset's analytical compression
//! ratio (Table II) within tolerance.

use cidertf::engine::{train, AlgoConfig, TrainConfig};
use cidertf::losses::Loss;
use cidertf::runtime::native::NativeBackend;
use cidertf::tensor::synth::SynthConfig;

fn cfg_for(algo: AlgoConfig, k: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new("tiny", Loss::Logit, algo);
    cfg.rank = 4;
    cfg.fiber_samples = 16;
    cfg.k = k;
    cfg.gamma = 0.25;
    cfg.iters_per_epoch = 120; // divisible by all taus used here
    cfg.epochs = 2;
    cfg.eval_batch = 64;
    cfg
}

fn bytes_for(algo: AlgoConfig) -> u64 {
    let data = SynthConfig::tiny(42).generate();
    let mut backend = NativeBackend::new();
    let out = train(&cfg_for(algo, 4), &data, &mut backend, None).unwrap();
    out.record.total.bytes
}

#[test]
fn every_preset_trains_without_error() {
    let data = SynthConfig::tiny(42).generate();
    for spec in [
        "cidertf:2",
        "cidertf_m:2",
        "dpsgd",
        "dpsgd_bras",
        "dpsgd_sign",
        "dpsgd_bras_sign",
        "sparq_sgd:2",
        "gcp",
        "bras_cpd",
        "centralized_cidertf",
    ] {
        let algo = AlgoConfig::by_name(spec).unwrap();
        let k = if matches!(spec, "gcp" | "bras_cpd" | "centralized_cidertf") { 1 } else { 4 };
        let mut backend = NativeBackend::new();
        let out = train(&cfg_for(algo, k), &data, &mut backend, None).unwrap();
        assert!(out.record.final_loss().is_finite(), "{spec} diverged");
        assert!(!out.record.points.is_empty());
    }
}

/// Sign compression must cut D-PSGD bytes by ~32x asymptotically; on the
/// tiny 32x4 factors the fixed 16-byte header + 4-byte scale dominate, so
/// the exact expectation is (16 + 4*128)/(16 + 4 + 16) = 14.67x. Verify
/// both the tiny-exact and the asymptotic behaviour.
#[test]
fn sign_compression_ratio_measured() {
    let dense = bytes_for(AlgoConfig::dpsgd());
    let sign = bytes_for(AlgoConfig::dpsgd_sign());
    let ratio = dense as f64 / sign as f64;
    assert!((13.0..16.0).contains(&ratio), "tiny sign ratio {ratio} (expect ~14.7)");
    // asymptotic check at production shape, pure payload math
    use cidertf::compress::Compressor;
    use cidertf::util::mat::Mat;
    use cidertf::util::rng::Rng;
    let m = Mat::rand_normal(320, 16, 1.0, &mut Rng::new(1));
    let big_ratio = Compressor::None.compress(&m).wire_bytes() as f64
        / Compressor::Sign.compress(&m).wire_bytes() as f64;
    assert!((29.0..32.1).contains(&big_ratio), "asymptotic ratio {big_ratio}");
}

/// Block randomization ships only the sampled mode; with D=3 and the
/// patient mode never travelling, expected bytes are ~(1/2 + 1/2 * uniform
/// over the 2 feature modes)... i.e. bras ships 1 feature-mode matrix on
/// 2/3 of rounds vs 2 matrices every round for D-PSGD.
#[test]
fn block_randomization_ratio_measured() {
    let dense = bytes_for(AlgoConfig::dpsgd());
    let bras = bytes_for(AlgoConfig::dpsgd_bras());
    let ratio = dense as f64 / bras as f64;
    // expectation: dense ships 2 feature matrices/round; bras ships 1 on
    // 2/3 of rounds -> ratio = 2 / (2/3) = 3 (= D). Allow sampling noise.
    assert!((2.2..4.0).contains(&ratio), "bras ratio {ratio}");
}

/// Periodic communication at tau divides comm rounds by tau.
#[test]
fn tau_scaling_measured() {
    let mut no_et_t2 = AlgoConfig::cidertf(2);
    no_et_t2.event_triggered = false;
    no_et_t2.name = "cider_noet_t2".into();
    let mut no_et_t8 = AlgoConfig::cidertf(8);
    no_et_t8.event_triggered = false;
    no_et_t8.name = "cider_noet_t8".into();
    let b2 = bytes_for(no_et_t2);
    let b8 = bytes_for(no_et_t8);
    let ratio = b2 as f64 / b8 as f64;
    assert!((2.5..5.5).contains(&ratio), "tau 2->8 ratio {ratio} (expect ~4)");
}

/// The event trigger can only reduce bytes relative to the same config
/// without it.
#[test]
fn event_trigger_only_reduces() {
    let with_et = bytes_for(AlgoConfig::cidertf(2));
    let mut no_et = AlgoConfig::cidertf(2);
    no_et.event_triggered = false;
    no_et.name = "cider_noet".into();
    let without = bytes_for(no_et);
    assert!(with_et <= without, "event trigger increased bytes: {with_et} vs {without}");
}

/// CiderTF's overall measured reduction must beat the Table II analytical
/// bound 1 - 1/(32 D tau) vs D-PSGD.
#[test]
fn cidertf_beats_analytic_bound() {
    let dense = bytes_for(AlgoConfig::dpsgd());
    let cider = bytes_for(AlgoConfig::cidertf(4));
    let measured = 1.0 - cider as f64 / dense as f64;
    let bound = AlgoConfig::cidertf(4).table2_ratio(3);
    assert!(
        measured >= bound - 0.01,
        "measured {measured} below analytic bound {bound}"
    );
}

/// Momentum state must not leak across presets (same name, different run).
#[test]
fn preset_runs_are_independent() {
    let b1 = bytes_for(AlgoConfig::cidertf(4));
    let b2 = bytes_for(AlgoConfig::cidertf(4));
    assert_eq!(b1, b2, "identical configs produced different byte counts");
}
