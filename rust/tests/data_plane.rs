//! Data-plane integration tests: loader round-trips (.tns / .bin / CSV),
//! the `file:`/`csv:` dataset sources through the registry, and — the
//! acceptance criterion — a file-backed dataset riding the full
//! spec → Session → checkpoint → resume pipeline bit-identically.

use std::path::PathBuf;

use cidertf::data::{bin, events, tns, DatasetSource};
use cidertf::engine::session::Session;
use cidertf::engine::spec::ExperimentSpec;
use cidertf::engine::{AlgoConfig, TrainOutcome};
use cidertf::losses::Loss;
use cidertf::net::driver::DriverKind;
use cidertf::registry;
use cidertf::runtime::native::NativeBackend;
use cidertf::tensor::synth::{SynthConfig, ValueKind};
use cidertf::tensor::SparseTensor;

fn tmp_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cidertf_data_plane_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn bits(t: &SparseTensor) -> Vec<u32> {
    t.vals.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn tns_and_bin_round_trip_a_generated_tensor_exactly() {
    let t = SynthConfig::tiny(33).with_values(ValueKind::Gaussian).generate().tensor;
    let dir = tmp_dir();

    let tns_path = dir.join("roundtrip.tns");
    tns::write_tns(&tns_path, &t).unwrap();
    let back = tns::load_tns(&tns_path).unwrap();
    assert_eq!(back.dims, t.dims);
    assert_eq!(back.nnz(), t.nnz());
    assert_eq!(back.idx, t.idx);
    assert_eq!(bits(&back), bits(&t), ".tns values must round-trip exactly");

    let bin_path = dir.join("roundtrip.bin");
    bin::write_bin(&bin_path, &t).unwrap();
    let back = bin::load_bin(&bin_path).unwrap();
    assert_eq!(back.dims, t.dims);
    assert_eq!(back.idx, t.idx);
    assert_eq!(bits(&back), bits(&t), ".bin values must round-trip exactly");
}

#[test]
fn file_source_loads_through_the_registry() {
    let t = SynthConfig::tiny(34).generate().tensor;
    let dir = tmp_dir();
    let path = dir.join("registry.tns");
    tns::write_tns(&path, &t).unwrap();
    let src = registry::datasets().resolve(&format!("file:{}", path.display())).unwrap();
    let data = src.load(ValueKind::Binary).unwrap();
    assert_eq!(data.tensor.dims, t.dims);
    assert_eq!(data.tensor.nnz(), t.nnz());
    assert!(data.truth.is_empty(), "loaded datasets have no planted truth");
}

#[test]
fn checked_in_example_tns_loads() {
    // the README example must actually work from a repo checkout
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/data/tiny.tns");
    let t = tns::load_tns(&path).unwrap();
    assert_eq!(t.dims, vec![4, 3, 2]);
    assert!(t.nnz() >= 4);
}

fn file_spec(dataset: &str, epochs: usize) -> ExperimentSpec {
    let mut spec = ExperimentSpec::builder(dataset, Loss::Logit, AlgoConfig::cidertf(2))
        .k(2)
        .rank(4)
        .fiber_samples(16)
        .gamma(0.5)
        .iters_per_epoch(30)
        .epochs(epochs)
        .eval_batch(32)
        .driver(DriverKind::Sim)
        .build()
        .unwrap();
    spec.backend = "native".to_string();
    spec
}

#[test]
fn file_dataset_rides_spec_session_checkpoint_resume_bit_identically() {
    let dir = tmp_dir();
    let tns_path = dir.join("e2e.tns");
    let t = SynthConfig::tiny(21).generate().tensor;
    tns::write_tns(&tns_path, &t).unwrap();
    let dataset = format!("file:{}", tns_path.display());

    // spec JSON round-trips the loader string
    let spec = file_spec(&dataset, 4);
    let back = ExperimentSpec::from_json_str(&spec.to_json().to_pretty_string()).unwrap();
    assert_eq!(back, spec);

    // the spec materializes the file, not a generator
    let data = spec.dataset_data().unwrap();
    assert_eq!(data.tensor.dims, t.dims);
    assert_eq!(data.tensor.nnz(), t.nnz());

    // uninterrupted reference run
    let mut backend = NativeBackend::new();
    let full: TrainOutcome =
        Session::new(spec.clone()).run_on(&data, &mut backend, None).unwrap();

    // truncated run with checkpointing...
    let ckpt = dir.join("e2e.ckpt.json");
    let mut backend = NativeBackend::new();
    Session::new(file_spec(&dataset, 2))
        .checkpoint_every(&ckpt, 1)
        .run_on(&data, &mut backend, None)
        .unwrap();

    // ...resumed via Session::run(), which re-loads the file from the
    // checkpointed spec through the dataset registry
    let mut resumed = Session::resume_from(&ckpt).unwrap();
    assert_eq!(resumed.spec().dataset, dataset, "loader spec survives the checkpoint");
    resumed.spec_mut().epochs = 4;
    let out = resumed.run().unwrap();

    for (m, (a, b)) in full.factors.mats.iter().zip(out.factors.mats.iter()).enumerate() {
        assert_eq!(a.data, b.data, "file-dataset resume diverged (mode {m})");
    }
    assert_eq!(full.record.points.len(), out.record.points.len());
    for (p, q) in full.record.points.iter().zip(out.record.points.iter()) {
        assert_eq!(p.loss, q.loss);
        assert_eq!(p.bytes, q.bytes);
        assert_eq!(p.time_s, q.time_s, "virtual clock diverged");
    }
    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn resume_rejects_a_changed_data_file() {
    // regenerating the file: source after checkpointing must fail loudly,
    // not silently continue on different data
    let dir = tmp_dir();
    let tns_path = dir.join("mutates.tns");
    tns::write_tns(&tns_path, &SynthConfig::tiny(40).generate().tensor).unwrap();
    let dataset = format!("file:{}", tns_path.display());
    let ckpt = dir.join("mutates.ckpt.json");
    let mut backend = NativeBackend::new();
    let data = file_spec(&dataset, 1).dataset_data().unwrap();
    Session::new(file_spec(&dataset, 1))
        .checkpoint_every(&ckpt, 1)
        .run_on(&data, &mut backend, None)
        .unwrap();

    // swap the file for a tensor with one extra entry (nnz guaranteed
    // to differ)
    let mut changed = SynthConfig::tiny(40).generate().tensor;
    let occupied = changed.cell_set();
    let free = (0..changed.n_cells() as u64)
        .find(|&lin| !occupied.contains(&lin))
        .expect("tiny tensor is sparse");
    let idx = cidertf::tensor::synth::delinearize(&changed.dims, free);
    changed.push(&idx, 1.0);
    tns::write_tns(&tns_path, &changed).unwrap();
    let mut resumed = Session::resume_from(&ckpt).unwrap();
    resumed.spec_mut().epochs = 2;
    let err = resumed.run();
    assert!(err.is_err(), "resume on a changed data file must error");
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("data source changed"), "{msg}");

    // same nnz, one value edited: caught by the content fingerprint
    let mut same_nnz = SynthConfig::tiny(40).generate().tensor;
    same_nnz.vals[0] = 2.0;
    tns::write_tns(&tns_path, &same_nnz).unwrap();
    let mut resumed = Session::resume_from(&ckpt).unwrap();
    resumed.spec_mut().epochs = 2;
    let err = resumed.run();
    assert!(err.is_err(), "resume on a same-nnz edit must error");
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("fingerprint"), "{msg}");
    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn csv_dataset_trains_through_the_session_pipeline() {
    let dir = tmp_dir();
    let csv_path = dir.join("events.csv");
    // 6 patients x 3 codes x 2 weeks of events, some repeated
    let mut rows = String::from("patient,code,time\n");
    for p in 0..6 {
        for (c, tm) in [(0, 0), (1, 0), (p % 3, 1)] {
            rows.push_str(&format!("p{p},dx{c},w{tm}\n"));
        }
    }
    std::fs::write(&csv_path, rows).unwrap();

    let (t, vocabs) = events::load_events_csv(&csv_path).unwrap();
    assert_eq!(t.dims, vec![6, 3, 2]);
    assert_eq!(vocabs.patients.len(), 6);

    let dataset = format!("csv:{}", csv_path.display());
    let spec = file_spec(&dataset, 1);
    let data = spec.dataset_data().unwrap();
    assert_eq!(data.tensor.dims, vec![6, 3, 2]);
    // logit runs binarize repeated events to {0,1} indicators
    assert!(data.tensor.vals.iter().all(|&v| v == 1.0));
    let mut backend = NativeBackend::new();
    let out = Session::new(spec).run_on(&data, &mut backend, None).unwrap();
    assert!(out.record.final_loss().is_finite());
}
