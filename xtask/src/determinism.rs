//! The schedule-fuzzing half of `cargo xtask verify --determinism`.
//!
//! Builds the release binary and proves three bit-level equalities the
//! repo's determinism contract promises (README "Verifying determinism"):
//!
//! 1. **Sweep schedule fuzz** — the built-in `--smoke` grid produces a
//!    byte-identical `sweep.jsonl` under 1, 2, and 4 workers. Worker
//!    count changes both the interleaving and the OS thread schedule, so
//!    each run exercises a different completion order.
//! 2. **Compute-thread fuzz** — a sim-driver training run produces a
//!    byte-identical checkpoint under 1, 2, and 4 compute threads (the
//!    fixed-lane reducers make partial-sum order invisible).
//! 3. **Seq-vs-sim driver equivalence** — under an ideal network the
//!    sequential and simulated drivers reach the same state. The seq
//!    driver timestamps points with the wall clock by design, so the
//!    comparison normalizes every `"time_s":<num>` value first; all
//!    remaining bytes (factors, RNG states, samplers, stats) must match.
//!
//! Everything runs out of a per-pid temp directory that is removed on
//! success and kept on failure for inspection.

use std::path::{Path, PathBuf};
use std::process::Command;

/// Tiny, fast training scenario shared by checks 2 and 3. `tiny` is the
/// 64x32x32 test tensor; two epochs keep the whole harness under a few
/// seconds per run while still crossing a checkpoint boundary.
const TRAIN_ARGS: &[&str] = &[
    "train",
    "--dataset",
    "tiny",
    "--epochs",
    "2",
    "--iters-per-epoch",
    "8",
    "--seed",
    "11",
];

fn run_cmd(program: &str, args: &[&str], cwd: &Path) -> Result<(), String> {
    let out = Command::new(program)
        .args(args)
        .current_dir(cwd)
        .output()
        .map_err(|e| format!("failed to spawn {program}: {e}"))?;
    if out.status.success() {
        return Ok(());
    }
    let tail = |b: &[u8]| {
        let s = String::from_utf8_lossy(b);
        let lines: Vec<&str> = s.lines().collect();
        lines[lines.len().saturating_sub(15)..].join("\n")
    };
    Err(format!(
        "`{program} {}` failed ({}):\n{}\n{}",
        args.join(" "),
        out.status,
        tail(&out.stdout),
        tail(&out.stderr)
    ))
}

fn read(path: &Path) -> Result<Vec<u8>, String> {
    std::fs::read(path).map_err(|e| format!("cannot read {}: {e}", path.display()))
}

/// Blank the numeric value after every `"time_s":` occurrence — the one
/// field the seq driver fills from the wall clock.
fn normalize_time_s(bytes: &[u8]) -> Vec<u8> {
    let key = b"\"time_s\":";
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0usize;
    while i < bytes.len() {
        if bytes[i..].starts_with(key) {
            out.extend_from_slice(key);
            i += key.len();
            while i < bytes.len()
                && (bytes[i].is_ascii_digit() || matches!(bytes[i], b'.' | b'-' | b'+' | b'e' | b'E'))
            {
                i += 1;
            }
            out.push(b'0');
        } else {
            out.push(bytes[i]);
            i += 1;
        }
    }
    out
}

/// Build the release binary and run the three checks. `repo_root` is the
/// workspace root (the xtask binary resolves it from its manifest dir).
pub fn run(repo_root: &Path) -> Result<(), String> {
    let cargo = std::env::var("CARGO").unwrap_or_else(|_| "cargo".to_string());
    println!("determinism: building release binary ...");
    run_cmd(&cargo, &["build", "--release", "--package", "cidertf"], repo_root)?;

    let target_dir = std::env::var("CARGO_TARGET_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| repo_root.join("target"));
    let bin_path = target_dir.join("release").join("cidertf");
    let bin = bin_path.to_string_lossy().to_string();

    let tmp = std::env::temp_dir().join(format!("cidertf-verify-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).map_err(|e| format!("cannot create {}: {e}", tmp.display()))?;
    let tmp_str = |p: PathBuf| p.to_string_lossy().to_string();

    // check 1: sweep schedule fuzz
    let mut sweeps: Vec<Vec<u8>> = Vec::new();
    for workers in ["1", "2", "4"] {
        let out_dir = tmp.join(format!("sweep_w{workers}"));
        let out_s = tmp_str(out_dir.clone());
        println!("determinism: sweep --smoke with {workers} worker(s) ...");
        run_cmd(
            &bin,
            &["sweep", "--smoke", "--fresh", "--workers", workers, "--out", &out_s],
            repo_root,
        )?;
        sweeps.push(read(&out_dir.join("sweep.jsonl"))?);
    }
    if sweeps.iter().any(|s| *s != sweeps[0]) {
        return Err(format!(
            "sweep.jsonl differs across 1/2/4 workers (kept for inspection under {})",
            tmp.display()
        ));
    }
    println!("determinism: sweep aggregate byte-identical across 1/2/4 workers");

    // check 2: compute-thread fuzz (sim driver, virtual clock)
    let mut ckpts: Vec<Vec<u8>> = Vec::new();
    for threads in ["1", "2", "4"] {
        let ckpt = tmp.join(format!("ckpt_sim_t{threads}.json"));
        let ckpt_s = tmp_str(ckpt.clone());
        let out_s = tmp_str(tmp.join(format!("train_sim_t{threads}")));
        println!("determinism: train --driver sim with {threads} thread(s) ...");
        let mut args: Vec<&str> = TRAIN_ARGS.to_vec();
        args.extend_from_slice(&[
            "--driver", "sim", "--threads", threads, "--checkpoint", &ckpt_s, "--out", &out_s,
        ]);
        run_cmd(&bin, &args, repo_root)?;
        ckpts.push(read(&ckpt)?);
    }
    if ckpts.iter().any(|c| *c != ckpts[0]) {
        return Err(format!(
            "sim checkpoint differs across 1/2/4 compute threads \
             (kept for inspection under {})",
            tmp.display()
        ));
    }
    let sim_t1 = ckpts.swap_remove(0);
    println!("determinism: sim checkpoint byte-identical across 1/2/4 threads");

    // check 3: seq-vs-sim driver equivalence (time_s normalized — the
    // seq driver reads the wall clock for it by design)
    let ckpt = tmp.join("ckpt_seq.json");
    let ckpt_s = tmp_str(ckpt.clone());
    let out_s = tmp_str(tmp.join("train_seq"));
    println!("determinism: train --driver seq (reference path) ...");
    let mut args: Vec<&str> = TRAIN_ARGS.to_vec();
    args.extend_from_slice(&[
        "--driver", "seq", "--threads", "1", "--checkpoint", &ckpt_s, "--out", &out_s,
    ]);
    run_cmd(&bin, &args, repo_root)?;
    let seq = normalize_time_s(&read(&ckpt)?);
    let sim = normalize_time_s(&sim_t1);
    if seq != sim {
        return Err(format!(
            "seq and sim checkpoints differ beyond time_s \
             (kept for inspection under {})",
            tmp.display()
        ));
    }
    println!("determinism: seq and sim drivers byte-identical (time_s normalized)");

    let _ = std::fs::remove_dir_all(&tmp);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::normalize_time_s;

    #[test]
    fn time_s_values_are_blanked() {
        let a = br#"{"t":3,"time_s":1.25e-3,"points":[{"time_s":-0.5,"loss":1.0}]}"#;
        let b = br#"{"t":3,"time_s":99.0,"points":[{"time_s":0.125,"loss":1.0}]}"#;
        assert_eq!(normalize_time_s(a), normalize_time_s(b));
        let n = normalize_time_s(a);
        let s = String::from_utf8(n).unwrap();
        assert!(s.contains(r#""time_s":0,"#));
        assert!(!s.contains("1.25e-3"));
    }

    #[test]
    fn non_time_bytes_are_untouched() {
        let a = br#"{"loss":1.25,"rng":[1,2,3]}"#;
        assert_eq!(normalize_time_s(a), a.to_vec());
    }
}
