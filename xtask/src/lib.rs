//! Library surface of the `xtask` verification tool, split out so the
//! fixture tests (`tests/lint_fixtures.rs`) can drive the lint engine
//! directly. The binary in `main.rs` is a thin dispatcher over these.

pub mod determinism;
pub mod lint;
