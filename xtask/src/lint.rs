//! The determinism + unsafe/concurrency lint engine behind
//! `cargo xtask verify`.
//!
//! An offline, line/token-based scanner over `rust/src` and `xtask/src`
//! (the linter lints itself) enforcing the repo-specific rules clippy
//! cannot express (module-scoped hazards, comparator-span analysis,
//! safety-contract presence). Comments, string literals, and char
//! literals are blanked by a small state machine before matching, so a
//! doc comment *describing* a hazard never trips a rule. `#[cfg(test)]`
//! items are skipped by tracking brace depth to the end of the annotated
//! item — test code cannot leak nondeterminism into run outputs, but
//! production code *below* a test module stays in scope.
//!
//! Rules (also tabulated in ARCHITECTURE.md "Static analysis &
//! invariants"):
//!
//! | id   | name                    | scope                      |
//! |------|-------------------------|----------------------------|
//! | D000 | malformed-allow         | everywhere                 |
//! | D001 | nan-ordering            | outside `util/order.rs`    |
//! | D002 | inline-float-sort       | outside `util/order.rs`    |
//! | D003 | hash-structure          | determinism-critical dirs  |
//! | D004 | wall-clock              | outside bench/harness/transport |
//! | D005 | unseeded-rng            | everywhere                 |
//! | D006 | float-sum               | determinism-critical dirs  |
//! | D007 | raw-thread-spawn        | outside `runtime/pool.rs`  |
//! | D008 | unsafe-containment      | outside `util/simd.rs` + `runtime/pool.rs` |
//! | D009 | missing-safety-contract | every `unsafe` token       |
//! | D010 | atomic-ordering         | every atomic `Ordering::` token |
//!
//! D009 wants a `// SAFETY: <why the invariants hold>` comment on the
//! line or up to three lines above each `unsafe` token; empty or
//! boilerplate justifications count as missing. D010 wants an
//! `// ordering: <why this memory order>` note at every atomic ordering
//! token, and additionally confines `Relaxed` to the annotated counters
//! in `runtime/pool.rs`.
//!
//! Escape hatch: `// lint: allow(<rule-name>) — <justification>` on the
//! flagged line or up to three lines above it (so a clippy attribute or
//! a continuation comment can sit between). An allow without a
//! justification, or naming an unknown rule, is itself a finding (D000).

use std::path::Path;

/// One lint rule: stable id, allow-name, and the diagnostic hint.
pub struct Rule {
    /// stable diagnostic id (`D001`)
    pub id: &'static str,
    /// the name `// lint: allow(<name>)` refers to
    pub name: &'static str,
    /// remediation hint appended to every diagnostic
    pub hint: &'static str,
}

/// The rule table. D000 is the meta-rule for malformed allows and is not
/// itself allowable.
pub const RULES: &[Rule] = &[
    Rule {
        id: "D000",
        name: "malformed-allow",
        hint: "every allow needs a known rule name and a justification",
    },
    Rule {
        id: "D001",
        name: "nan-ordering",
        hint: "partial_cmp is None on NaN (panicking unwraps, inconsistent sorts); \
               use the total comparators in util/order.rs",
    },
    Rule {
        id: "D002",
        name: "inline-float-sort",
        hint: "hand-rolled NaN handling inside a comparator callback; \
               use the nan_last_* helpers in util/order.rs",
    },
    Rule {
        id: "D003",
        name: "hash-structure",
        hint: "HashMap/HashSet iteration order is unseeded and can leak into outputs \
               in a determinism-critical module; use BTreeMap/BTreeSet, or justify \
               why order cannot escape",
    },
    Rule {
        id: "D004",
        name: "wall-clock",
        hint: "wall-clock reads outside the bench/harness allowlist; deterministic \
               paths must take time from the virtual clock",
    },
    Rule {
        id: "D005",
        name: "unseeded-rng",
        hint: "randomness must flow from the run seed (util/rng); ambient entropy \
               breaks bit-exact replay",
    },
    Rule {
        id: "D006",
        name: "float-sum",
        hint: "free-form float summation in a determinism-critical module; use the \
               fixed-lane reducers in util/mat.rs",
    },
    Rule {
        id: "D007",
        name: "raw-thread-spawn",
        hint: "raw std::thread::spawn/scope outside the worker pool; route parallel \
               work through runtime::pool::parallel_for (persistent workers, \
               deterministic job order), or justify the long-lived/barrier-structured \
               exception",
    },
    Rule {
        id: "D008",
        name: "unsafe-containment",
        hint: "unsafe code is audited (Miri/TSan lanes, the pool model checker) only \
               in util/simd.rs and runtime/pool.rs; move it behind those modules' \
               safe APIs, or justify an audited exception",
    },
    Rule {
        id: "D009",
        name: "missing-safety-contract",
        hint: "every unsafe site needs a `// SAFETY:` contract on the line or up to \
               3 lines above stating why the invariants hold; empty or boilerplate \
               justifications count as missing",
    },
    Rule {
        id: "D010",
        name: "atomic-ordering",
        hint: "every atomic Ordering:: token needs an `// ordering:` note justifying \
               the memory-order choice; Relaxed is allowed only at annotated \
               counters in runtime/pool.rs",
    },
];

/// Files where `unsafe` is allowed to live (D008): the audited SIMD
/// kernels and the worker pool — the two surfaces covered by the Miri
/// and TSan CI lanes plus the pool model checker.
const UNSAFE_ALLOWED: &[&str] = &["util/simd.rs", "runtime/pool.rs"];

/// The atomic memory-ordering variants D010 tracks. `std::cmp::Ordering`
/// variants (Less/Equal/Greater) are deliberately absent.
const ATOMIC_ORDERINGS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// Directories under `rust/src` where hash-order and float-sum hazards
/// feed run outputs (aggregates, checkpoints, NetStats).
const CRITICAL_DIRS: &[&str] = &["engine/", "gossip/", "sweep/", "net/", "tensor/", "compress/"];

/// Files allowed to read the wall clock: the timing harness itself, plus
/// the node transport edge (`node/transport.rs`), whose socket dial
/// deadlines and reconnect backoff are genuinely wall-clock-dependent.
/// The rest of `node/` (daemon round loop, fleet merge) stays under D004
/// — deterministic state must take time from the virtual clock.
fn wall_clock_allowed(rel: &str) -> bool {
    rel == "util/benchkit.rs" || rel == "node/transport.rs" || rel.starts_with("harness/")
}

/// One diagnostic.
#[derive(Debug)]
pub struct Finding {
    /// rule id (`D003`)
    pub rule_id: &'static str,
    /// rule allow-name (`hash-structure`)
    pub rule_name: &'static str,
    /// path as reported (relative to `rust/src` from [`lint_source`];
    /// [`run`] rewrites it repo-relative)
    pub file: String,
    /// 1-based line
    pub line: usize,
    /// what was matched + the rule hint
    pub message: String,
}

impl Finding {
    /// `D003 [hash-structure] rust/src/net/sim.rs:396 — ...`
    pub fn render(&self) -> String {
        format!(
            "{} [{}] {}:{} — {}",
            self.rule_id, self.rule_name, self.file, self.line, self.message
        )
    }
}

struct Allow {
    line: usize,
    rule: String,
    justified: bool,
}

/// Blank comments, string literals, and char literals, preserving line
/// structure (every line keeps its index; matched tokens keep their
/// columns). Block comments nest; raw strings, escaped chars, and
/// backslash-continued strings are handled; lifetimes survive.
fn strip(source: &str) -> Vec<String> {
    #[derive(Clone, Copy)]
    enum S {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(usize),
    }
    let chars: Vec<char> = source.chars().collect();
    let mut out = String::with_capacity(source.len());
    let mut state = S::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        let next = chars.get(i + 1).copied();
        match state {
            S::Code => {
                if c == '/' && next == Some('/') {
                    state = S::LineComment;
                    out.push_str("  ");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = S::BlockComment(1);
                    out.push_str("  ");
                    i += 2;
                } else if c == 'r' && (next == Some('"') || next == Some('#')) {
                    // possible raw string r"..." / r#"..."#
                    let mut j = i + 1;
                    let mut hashes = 0usize;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        state = S::RawStr(hashes);
                        for _ in i..=j {
                            out.push(' ');
                        }
                        i = j + 1;
                    } else {
                        out.push(c);
                        i += 1;
                    }
                } else if c == '"' {
                    state = S::Str;
                    out.push(' ');
                    i += 1;
                } else if c == '\'' {
                    if next == Some('\\') {
                        // escaped char literal: blank through the close
                        let mut j = i + 3; // past the escape lead char
                        while j < chars.len() && chars[j] != '\'' {
                            j += 1;
                        }
                        let end = j.min(chars.len().saturating_sub(1));
                        for _ in i..=end {
                            out.push(' ');
                        }
                        i = end + 1;
                    } else if next.is_some() && chars.get(i + 2) == Some(&'\'') {
                        // plain char literal 'x'
                        out.push_str("   ");
                        i += 3;
                    } else {
                        // lifetime
                        out.push(c);
                        i += 1;
                    }
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            S::LineComment => {
                if c == '\n' {
                    state = S::Code;
                    out.push('\n');
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            S::BlockComment(depth) => {
                if c == '/' && next == Some('*') {
                    state = S::BlockComment(depth + 1);
                    out.push_str("  ");
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if depth == 1 { S::Code } else { S::BlockComment(depth - 1) };
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(if c == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            S::Str => {
                if c == '\\' && next.is_some() {
                    out.push(' ');
                    out.push(if next == Some('\n') { '\n' } else { ' ' });
                    i += 2;
                } else if c == '"' {
                    state = S::Code;
                    out.push(' ');
                    i += 1;
                } else {
                    out.push(if c == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            S::RawStr(hashes) => {
                if c == '"' && (0..hashes).all(|k| chars.get(i + 1 + k) == Some(&'#')) {
                    for _ in 0..=hashes {
                        out.push(' ');
                    }
                    i += hashes + 1;
                    state = S::Code;
                } else {
                    out.push(if c == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
        }
    }
    out.split('\n').map(|l| l.to_string()).collect()
}

/// Parse `// lint: allow(<rule>) — <justification>` annotations from the
/// raw (unstripped) lines.
fn parse_allows(source: &str) -> Vec<Allow> {
    let mut allows = Vec::new();
    for (idx, line) in source.lines().enumerate() {
        let Some(pos) = line.find("lint: allow(") else { continue };
        // the marker must live in a line comment
        if !line[..pos].contains("//") {
            continue;
        }
        let rest = &line[pos + "lint: allow(".len()..];
        let Some(close) = rest.find(')') else { continue };
        let rule = rest[..close].trim().to_string();
        let justification = rest[close + 1..]
            .trim_matches(|c: char| c.is_whitespace() || c == '—' || c == '-' || c == ':');
        allows.push(Allow {
            line: idx + 1,
            rule,
            justified: !justification.trim().is_empty(),
        });
    }
    allows
}

/// Per-line scan mask: `true` = the line is production code in scope
/// for the rules. Each `#[cfg(test)]` attribute masks its annotated item
/// by tracking brace depth from the attribute to the item's closing
/// brace (or to a `;` before any brace opens — `#[cfg(test)] mod t;` /
/// `#[cfg(test)] use …;`). Unbalanced braces mask to end of file, which
/// matches the old skip-to-EOF behavior for the trailing-test-module
/// convention — but production code *below* a balanced test item stays
/// scanned.
fn scan_mask(stripped: &[String]) -> Vec<bool> {
    let mut mask = vec![true; stripped.len()];
    let mut i = 0usize;
    while i < stripped.len() {
        let Some(pos) = stripped[i].find("#[cfg(test)]") else {
            i += 1;
            continue;
        };
        let start_col = pos + "#[cfg(test)]".len();
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut end = stripped.len() - 1;
        'span: for (j, line) in stripped.iter().enumerate().skip(i) {
            let tail = if j == i { &line[start_col..] } else { line.as_str() };
            for c in tail.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth <= 0 {
                            end = j;
                            break 'span;
                        }
                    }
                    ';' if !opened => {
                        end = j;
                        break 'span;
                    }
                    _ => {}
                }
            }
        }
        for m in mask.iter_mut().take(end + 1).skip(i) {
            *m = false;
        }
        i = end + 1;
    }
    mask
}

/// Whole-word occurrence of `word` (identifier-boundary on both sides)
/// in an already-stripped line.
fn has_word(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let mut from = 0usize;
    while let Some(p) = line[from..].find(word) {
        let at = from + p;
        let left_ok =
            at == 0 || !(bytes[at - 1].is_ascii_alphanumeric() || bytes[at - 1] == b'_');
        let after = at + word.len();
        let right_ok = after >= bytes.len()
            || !(bytes[after].is_ascii_alphanumeric() || bytes[after] == b'_');
        if left_ok && right_ok {
            return true;
        }
        from = after;
    }
    false
}

/// D009 verdict for one `unsafe` token line.
enum Contract {
    /// No `// SAFETY:` marker on the line or within 3 lines above.
    Missing,
    /// A marker exists but its justification is empty or boilerplate.
    Weak,
    Ok,
}

/// Look for a `// SAFETY: <justification>` comment covering 1-based
/// `line` (same line or up to 3 lines above, mirroring the allow
/// window), reading the *raw* lines so comment text is visible. The
/// justification is the marker's tail plus any directly following
/// comment-only continuation lines; a normalized justification shorter
/// than 10 characters, or matching a known brush-off, is `Weak`.
fn safety_contract(raw: &[&str], line: usize) -> Contract {
    let lo = line.saturating_sub(4);
    let mut marker: Option<(usize, usize)> = None;
    for (idx, l) in raw.iter().enumerate().take(line).skip(lo) {
        if let Some(p) = l.find("SAFETY:") {
            if l[..p].contains("//") {
                marker = Some((idx, p + "SAFETY:".len()));
            }
        }
    }
    let Some((mi, mp)) = marker else { return Contract::Missing };
    let mut text = raw[mi][mp..].trim().to_string();
    for l in raw.iter().take(line.saturating_sub(1)).skip(mi + 1) {
        let t = l.trim_start();
        if t.starts_with("//") {
            text.push(' ');
            text.push_str(t.trim_start_matches('/').trim_start_matches('!').trim());
        } else {
            break;
        }
    }
    let norm: String =
        text.chars().filter(char::is_ascii_alphanumeric).collect::<String>().to_lowercase();
    const BOILERPLATE: &[&str] =
        &["safe", "ok", "fine", "thisissafe", "itissafe", "triviallysafe", "knownsafe"];
    if norm.len() < 10 || BOILERPLATE.contains(&norm.as_str()) {
        Contract::Weak
    } else {
        Contract::Ok
    }
}

/// D010: is there an `// ordering: <why>` note (non-empty tail) on
/// 1-based `line` or within 3 lines above it, in the raw lines?
fn has_ordering_note(raw: &[&str], line: usize) -> bool {
    let lo = line.saturating_sub(4);
    for l in raw.iter().take(line).skip(lo) {
        if let Some(p) = l.find("ordering:") {
            let left_word = p > 0
                && (l.as_bytes()[p - 1].is_ascii_alphanumeric() || l.as_bytes()[p - 1] == b'_');
            if !left_word && l[..p].contains("//") && !l[p + "ordering:".len()..].trim().is_empty()
            {
                return true;
            }
        }
    }
    false
}

/// D002: scan `*_by(` comparator callbacks (sort_by, sort_unstable_by,
/// select_nth_unstable_by, max_by, ...) for hand-rolled `is_nan` handling
/// anywhere in the balanced-paren span.
fn comparator_findings(stripped: &[String], mask: &[bool], out: &mut Vec<Finding>) {
    let joined = stripped.join("\n");
    let bytes = joined.as_bytes();
    let mut search = 0usize;
    while let Some(p) = joined[search..].find("_by(") {
        let at = search + p;
        let open = at + 3; // the '('
        search = open + 1;
        let line = joined[..at].bytes().filter(|&b| b == b'\n').count() + 1;
        if !mask[line - 1] {
            continue;
        }
        let mut depth = 0usize;
        let mut end = bytes.len();
        for (k, &b) in bytes[open..].iter().enumerate() {
            if b == b'(' {
                depth += 1;
            } else if b == b')' {
                depth -= 1;
                if depth == 0 {
                    end = open + k;
                    break;
                }
            }
        }
        if joined[open..end].contains("is_nan") {
            push_finding(out, "D002", "comparator callback hand-rolls NaN ordering", line);
        }
    }
}

fn push_finding(out: &mut Vec<Finding>, id: &str, what: &str, line: usize) {
    let rule = RULES.iter().find(|r| r.id == id).expect("known rule id");
    out.push(Finding {
        rule_id: rule.id,
        rule_name: rule.name,
        file: String::new(), // filled by the caller
        line,
        message: format!("{what}; {}", rule.hint),
    });
}

/// Lint one file. `rel` is the path relative to `rust/src` with `/`
/// separators (it drives the per-module scoping); `source` is the raw
/// file text. Pure — the fixture tests drive this directly.
pub fn lint_source(rel: &str, source: &str) -> Vec<Finding> {
    let stripped = strip(source);
    let allows = parse_allows(source);
    let raw_lines: Vec<&str> = source.lines().collect();

    // mask out `#[cfg(test)]` items (brace-depth tracked — production
    // code below a test module stays in scope; test code cannot reach
    // run outputs)
    let mask = scan_mask(&stripped);

    let critical = CRITICAL_DIRS.iter().any(|d| rel.starts_with(d));
    let order_rs = rel == "util/order.rs";
    let clock_ok = wall_clock_allowed(rel);
    let pool_rs = rel == "runtime/pool.rs";
    let unsafe_ok = UNSAFE_ALLOWED.contains(&rel);

    let mut raw: Vec<Finding> = Vec::new();
    for (idx, line) in stripped.iter().enumerate() {
        if !mask[idx] {
            continue;
        }
        let ln = idx + 1;
        if !order_rs && line.contains(".partial_cmp(") {
            push_finding(&mut raw, "D001", "raw `.partial_cmp(` call", ln);
        }
        if critical {
            for token in ["HashMap", "HashSet"] {
                if line.contains(token) {
                    push_finding(
                        &mut raw,
                        "D003",
                        &format!("`{token}` in a determinism-critical module"),
                        ln,
                    );
                }
            }
            for token in [".sum::<f32>()", ".sum::<f64>()"] {
                if line.contains(token) {
                    push_finding(
                        &mut raw,
                        "D006",
                        &format!("`{token}` in a determinism-critical module"),
                        ln,
                    );
                }
            }
        }
        if !clock_ok {
            for token in ["Instant::now", "SystemTime"] {
                if line.contains(token) {
                    push_finding(&mut raw, "D004", &format!("`{token}` wall-clock read"), ln);
                }
            }
        }
        for token in ["thread_rng", "from_entropy", "rand::random", "RandomState", "getrandom"] {
            if line.contains(token) {
                push_finding(&mut raw, "D005", &format!("`{token}` unseeded randomness"), ln);
            }
        }
        if !pool_rs {
            for token in ["thread::spawn", "thread::scope"] {
                if line.contains(token) {
                    push_finding(
                        &mut raw,
                        "D007",
                        &format!("`{token}` outside the worker pool"),
                        ln,
                    );
                }
            }
        }
        if has_word(line, "unsafe") {
            if !unsafe_ok {
                push_finding(&mut raw, "D008", "`unsafe` outside the audited allowlist", ln);
            }
            match safety_contract(&raw_lines, ln) {
                Contract::Missing => {
                    push_finding(&mut raw, "D009", "`unsafe` without a `// SAFETY:` contract", ln);
                }
                Contract::Weak => {
                    push_finding(
                        &mut raw,
                        "D009",
                        "`unsafe` with an empty or boilerplate `// SAFETY:` justification",
                        ln,
                    );
                }
                Contract::Ok => {}
            }
        }
        for ord in ATOMIC_ORDERINGS {
            let token = format!("Ordering::{ord}");
            if !has_word(line, &token) {
                continue;
            }
            if !has_ordering_note(&raw_lines, ln) {
                push_finding(
                    &mut raw,
                    "D010",
                    &format!("atomic `{token}` without an `// ordering:` note"),
                    ln,
                );
            }
            if *ord == "Relaxed" && !pool_rs {
                push_finding(
                    &mut raw,
                    "D010",
                    "`Ordering::Relaxed` outside runtime/pool.rs",
                    ln,
                );
            }
        }
    }
    if !order_rs {
        comparator_findings(&stripped, &mask, &mut raw);
    }

    // apply allows: an annotation suppresses its rule on the same line or
    // up to 3 lines below the annotation
    let mut findings: Vec<Finding> = raw
        .into_iter()
        .filter(|f| {
            !allows.iter().any(|a| {
                a.rule == f.rule_name && a.line <= f.line && f.line <= a.line + 3
            })
        })
        .collect();

    // D000: malformed allows (unknown rule / missing justification) are
    // findings themselves and cannot be allowed away
    for a in &allows {
        if !RULES.iter().any(|r| r.name == a.rule) {
            push_finding(
                &mut findings,
                "D000",
                &format!("allow names unknown rule '{}'", a.rule),
                a.line,
            );
        } else if !a.justified {
            push_finding(
                &mut findings,
                "D000",
                &format!("allow({}) has no justification", a.rule),
                a.line,
            );
        }
    }

    for f in findings.iter_mut() {
        f.file = rel.to_string();
    }
    findings.sort_by(|a, b| a.line.cmp(&b.line).then(a.rule_id.cmp(b.rule_id)));
    findings
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("cannot read {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("cannot read {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// The source roots [`run`] scans: the crate, and the linter itself
/// (self-lint — D000/D005-class rules apply to xtask too).
const ROOTS: &[(&[&str], &str)] = &[(&["rust", "src"], "rust/src"), (&["xtask", "src"], "xtask/src")];

/// Every file [`run`] scans, as `(absolute path, repo-relative display
/// path)`, in root order then sorted path order.
pub fn scanned_files(repo_root: &Path) -> Result<Vec<(std::path::PathBuf, String)>, String> {
    let mut out = Vec::new();
    for (segments, prefix) in ROOTS {
        let root = segments.iter().fold(repo_root.to_path_buf(), |p, s| p.join(s));
        let mut files = Vec::new();
        collect_rs(&root, &mut files)?;
        files.sort();
        for path in files {
            let rel = path
                .strip_prefix(&root)
                .expect("file under scan root")
                .to_string_lossy()
                .replace('\\', "/");
            out.push((path, format!("{prefix}/{rel}")));
        }
    }
    Ok(out)
}

/// Lint every `.rs` file under `<repo_root>/rust/src` and
/// `<repo_root>/xtask/src`, in sorted path order per root. Returns the
/// findings (empty = clean tree).
pub fn run(repo_root: &Path) -> Result<Vec<Finding>, String> {
    let mut all = Vec::new();
    for (path, display) in scanned_files(repo_root)? {
        // the scoping key: rust/src files keep their old module-relative
        // form (`runtime/pool.rs`); xtask files keep the full prefix, so
        // no allowlist (pool/simd/order/benchkit) can match them
        let rel = display.strip_prefix("rust/src/").unwrap_or(&display).to_string();
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        for mut f in lint_source(&rel, &text) {
            f.file = display.clone();
            all.push(f);
        }
    }
    Ok(all)
}
