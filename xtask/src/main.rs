//! `cargo xtask verify [--determinism]` — the determinism firewall and
//! unsafe/concurrency auditor.
//!
//! * `verify` runs the in-repo lint engine (see `lint.rs`) over
//!   `rust/src` and `xtask/src` and exits nonzero on any finding.
//! * `verify --determinism` additionally builds the release binary and
//!   runs the schedule-fuzzing harness (see `determinism.rs`).
//!
//! Invoked through the `.cargo/config.toml` alias; works offline with
//! zero dependencies.

use std::path::PathBuf;

fn repo_root() -> PathBuf {
    // xtask lives at <repo>/xtask, so the workspace root is one up from
    // this crate's manifest dir
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask crate sits inside the workspace")
        .to_path_buf()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut determinism = false;
    let mut verify = false;
    for a in &args {
        match a.as_str() {
            "verify" => verify = true,
            "--determinism" => determinism = true,
            "help" | "--help" | "-h" => {
                print_help();
                return;
            }
            other => {
                eprintln!("xtask: unknown argument '{other}'\n");
                print_help();
                std::process::exit(2);
            }
        }
    }
    if !verify {
        print_help();
        std::process::exit(2);
    }

    let root = repo_root();
    match xtask::lint::run(&root) {
        Ok(findings) if findings.is_empty() => {
            println!("lint: rust/src + xtask/src clean ({} rules)", xtask::lint::RULES.len());
        }
        Ok(findings) => {
            for f in &findings {
                eprintln!("{}", f.render());
            }
            eprintln!("\nlint: {} finding(s)", findings.len());
            eprintln!(
                "(suppress a deliberate site with `// lint: allow(<rule>) — <justification>` \
                 on or up to 3 lines above it; see ARCHITECTURE.md \"Static analysis & invariants\")"
            );
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("lint: {e}");
            std::process::exit(1);
        }
    }

    if determinism {
        if let Err(e) = xtask::determinism::run(&root) {
            eprintln!("determinism: FAILED\n{e}");
            std::process::exit(1);
        }
        println!("determinism: all schedule-fuzz checks passed");
    }
}

fn print_help() {
    println!(
        "cargo xtask verify [--determinism]\n\
         \n\
         verify          lint rust/src + xtask/src with the determinism and\n\
                         unsafe/concurrency rules (D000-D010)\n\
         --determinism   also build the release binary and prove byte-identical\n\
                         outputs across worker schedules, compute-thread counts,\n\
                         and the seq/sim driver pair"
    );
}
