// Known-bad fixture for D008 (unsafe-containment). Not compiled — fed
// to the lint engine as text by tests/lint_fixtures.rs under a path
// outside the audited allowlist (util/simd.rs, runtime/pool.rs). The
// contract is real so D009 stays quiet and only D008 trips.

pub fn worst(p: *mut f32) -> f32 {
    // SAFETY: the caller guarantees `p` points at a live, aligned f32
    // for the duration of this call.
    unsafe { *p }
}
