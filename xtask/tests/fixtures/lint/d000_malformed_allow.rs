// Known-bad fixture for D000 (malformed-allow). Not compiled — fed to
// the lint engine as text by tests/lint_fixtures.rs.

// lint: allow(totally-bogus) — misspelled rule names must not pass silently
pub fn suppressed_by_typo() {}

// lint: allow(nan-ordering)
pub fn suppressed_without_justification() {}
