// Known-bad fixture for D003 (hash-structure). Not compiled — fed to
// the lint engine as text by tests/lint_fixtures.rs under a
// determinism-critical path (engine/).

pub fn worst(pairs: &[(u64, f32)]) -> Vec<u64> {
    let mut m = std::collections::HashMap::new();
    for &(k, v) in pairs {
        m.insert(k, v);
    }
    m.into_keys().collect()
}
