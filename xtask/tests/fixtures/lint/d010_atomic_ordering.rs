// Known-bad fixture for D010 (atomic-ordering). Not compiled — fed to
// the lint engine as text by tests/lint_fixtures.rs: one access with
// no memory-model note, plus an annotated Relaxed outside the pool.

use std::sync::atomic::{AtomicUsize, Ordering};

pub fn no_note(c: &AtomicUsize) -> usize {
    c.load(Ordering::SeqCst)
}

pub fn relaxed_outside_pool(c: &AtomicUsize) {
    // ordering: Relaxed — a stat counter, but this is not pool.rs
    c.store(1, Ordering::Relaxed);
}
