// Known-bad fixture for D006 (float-sum). Not compiled — fed to the
// lint engine as text by tests/lint_fixtures.rs under a
// determinism-critical path (engine/).

pub fn worst(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>()
}
