// Known-bad fixture for D004 (wall-clock). Not compiled — fed to the
// lint engine as text by tests/lint_fixtures.rs under a path outside
// the bench/harness allowlist.

pub fn worst() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos()
}
