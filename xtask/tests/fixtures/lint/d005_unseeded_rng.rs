// Known-bad fixture for D005 (unseeded-rng). Not compiled — fed to the
// lint engine as text by tests/lint_fixtures.rs.

pub fn worst() -> f64 {
    rand::random::<f64>()
}
