// Regression fixture for the scan mask: a *balanced* #[cfg(test)]
// item mid-file must not hide the production code after it (the old
// scanner skipped from the first #[cfg(test)] to end of file). Fed to
// the lint engine as text by tests/lint_fixtures.rs.

pub fn fine() -> u32 {
    7
}

#[cfg(test)]
mod tests {
    #[test]
    fn hazards_in_tests_are_invisible() {
        let _ = std::time::Instant::now();
    }
}

#[cfg(test)]
use std::time::SystemTime as TestOnlyAlias;

pub fn worst() -> u128 {
    std::time::Instant::now().elapsed().as_nanos()
}
