// Known-bad fixture for D001 (nan-ordering). Not compiled — fed to the
// lint engine as text by tests/lint_fixtures.rs.

pub fn worst(a: f64, b: f64) -> bool {
    a.partial_cmp(&b).unwrap() == std::cmp::Ordering::Less
}
