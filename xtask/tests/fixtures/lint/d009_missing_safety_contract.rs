// Known-bad fixture for D009 (missing-safety-contract). Not compiled —
// fed to the lint engine as text by tests/lint_fixtures.rs under an
// allowlisted path so D008 stays quiet and only D009 trips: one
// contract-less site, one brushed-off contract.

pub fn no_contract(p: *mut f32) -> f32 {
    unsafe { *p }
}

// SAFETY: safe
pub fn boilerplate_contract(p: *mut f32) -> f32 {
    unsafe { *p }
}
