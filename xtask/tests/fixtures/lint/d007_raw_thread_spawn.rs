//! Fixture: raw thread spawns outside the pool module (D007).

pub fn bad_spawn() -> u64 {
    let h = std::thread::spawn(|| 1 + 1);
    h.join().unwrap()
}

pub fn bad_scope(xs: &mut [u64]) {
    std::thread::scope(|s| {
        for x in xs.iter_mut() {
            s.spawn(move || *x += 1);
        }
    });
}
