// Known-bad fixture for D002 (inline-float-sort). Not compiled — fed to
// the lint engine as text by tests/lint_fixtures.rs.

pub fn worst(v: &mut [f32]) {
    v.sort_by(|a, b| {
        if a.is_nan() {
            std::cmp::Ordering::Greater
        } else if b.is_nan() {
            std::cmp::Ordering::Less
        } else {
            a.total_cmp(b)
        }
    });
}
