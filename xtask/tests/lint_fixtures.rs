//! Fixture tests for the determinism lint engine (ISSUE: every rule has
//! a known-bad snippet that trips exactly that rule, and a
//! `// lint: allow(...)` annotation suppresses it).
//!
//! The fixtures under `fixtures/lint/` are plain text to the engine —
//! cargo never compiles them (only top-level files in `tests/` become
//! test targets).

use xtask::lint::{lint_source, RULES};

/// (source, rel path under rust/src, rule id, rule name) — the path
/// places each fixture where its rule is in scope (e.g. `engine/` for
/// the determinism-critical-module rules).
const CASES: &[(&str, &str, &str, &str)] = &[
    (
        include_str!("fixtures/lint/d001_nan_ordering.rs"),
        "factor/fixture.rs",
        "D001",
        "nan-ordering",
    ),
    (
        include_str!("fixtures/lint/d002_inline_float_sort.rs"),
        "factor/fixture.rs",
        "D002",
        "inline-float-sort",
    ),
    (
        include_str!("fixtures/lint/d003_hash_structure.rs"),
        "engine/fixture.rs",
        "D003",
        "hash-structure",
    ),
    (
        include_str!("fixtures/lint/d004_wall_clock.rs"),
        "util/fixture.rs",
        "D004",
        "wall-clock",
    ),
    (
        include_str!("fixtures/lint/d005_unseeded_rng.rs"),
        "data/fixture.rs",
        "D005",
        "unseeded-rng",
    ),
    (
        include_str!("fixtures/lint/d006_float_sum.rs"),
        "engine/fixture.rs",
        "D006",
        "float-sum",
    ),
    (
        include_str!("fixtures/lint/d007_raw_thread_spawn.rs"),
        "sweep/fixture.rs",
        "D007",
        "raw-thread-spawn",
    ),
    (
        include_str!("fixtures/lint/d008_unsafe_containment.rs"),
        "engine/fixture.rs",
        "D008",
        "unsafe-containment",
    ),
    (
        include_str!("fixtures/lint/d009_missing_safety_contract.rs"),
        "util/simd.rs",
        "D009",
        "missing-safety-contract",
    ),
    (
        include_str!("fixtures/lint/d010_atomic_ordering.rs"),
        "engine/fixture.rs",
        "D010",
        "atomic-ordering",
    ),
];

#[test]
fn rule_table_is_well_formed() {
    for r in RULES {
        assert!(r.id.starts_with('D') && r.id.len() == 4, "bad id {}", r.id);
        assert!(!r.name.is_empty() && !r.hint.is_empty());
    }
    let mut ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), RULES.len(), "duplicate rule ids");
    let mut names: Vec<&str> = RULES.iter().map(|r| r.name).collect();
    names.sort();
    names.dedup();
    assert_eq!(names.len(), RULES.len(), "duplicate rule names");
    // every CASES entry references a real rule
    for &(_, _, id, name) in CASES {
        assert!(RULES.iter().any(|r| r.id == id && r.name == name), "{id} missing");
    }
}

#[test]
fn each_fixture_trips_exactly_its_rule() {
    for &(src, rel, id, _) in CASES {
        let findings = lint_source(rel, src);
        assert!(!findings.is_empty(), "{id} fixture tripped nothing");
        for f in &findings {
            assert_eq!(f.rule_id, id, "{id} fixture tripped {}: {}", f.rule_id, f.render());
        }
    }
}

#[test]
fn malformed_allow_fixture_trips_only_d000() {
    let src = include_str!("fixtures/lint/d000_malformed_allow.rs");
    let findings = lint_source("data/fixture.rs", src);
    assert_eq!(findings.len(), 2, "expected unknown-rule + missing-justification");
    for f in &findings {
        assert_eq!(f.rule_id, "D000", "{}", f.render());
    }
}

/// Insert a justified allow annotation directly above every finding line
/// and assert the fixture lints clean.
#[test]
fn allow_annotation_suppresses_each_fixture() {
    for &(src, rel, id, name) in CASES {
        let mut lines: Vec<usize> =
            lint_source(rel, src).iter().map(|f| f.line).collect();
        lines.sort_unstable();
        lines.dedup();
        let mut patched: Vec<String> = src.lines().map(|l| l.to_string()).collect();
        for &n in lines.iter().rev() {
            patched.insert(n - 1, format!("// lint: allow({name}) — fixture justification"));
        }
        let after = lint_source(rel, &patched.join("\n"));
        assert!(
            after.is_empty(),
            "{id} fixture still trips after allow: {:?}",
            after.iter().map(|f| f.render()).collect::<Vec<_>>()
        );
    }
}

#[test]
fn same_line_allow_also_suppresses() {
    let src = "pub fn f() -> std::time::Instant {\n    \
               std::time::Instant::now() // lint: allow(wall-clock) — fixture justification\n}\n";
    assert!(lint_source("util/fixture.rs", src).is_empty());
}

#[test]
fn comments_strings_and_test_code_are_invisible() {
    // a doc comment describing the hazard is not the hazard
    let src = "//! HashMap iteration order and Instant::now are banned here.\npub fn f() {}\n";
    assert!(lint_source("engine/doc.rs", src).is_empty());
    // string literals are blanked before matching
    let src = "pub fn f() -> &'static str {\n    \"thread_rng and SystemTime\"\n}\n";
    assert!(lint_source("engine/strs.rs", src).is_empty());
    // #[cfg(test)] items are masked (here: a trailing test module)
    let src = "pub fn f() {}\n\n#[cfg(test)]\nmod tests {\n    \
               use std::collections::HashMap;\n    \
               fn g() { let _ = std::time::Instant::now(); }\n}\n";
    assert!(lint_source("engine/tested.rs", src).is_empty());
}

#[test]
fn balanced_test_module_does_not_hide_later_code() {
    // regression: the old scanner skipped from the first #[cfg(test)] to
    // end of file, hiding any production code below a test item
    let src = include_str!("fixtures/lint/nontrailing_test_mod.rs");
    let findings = lint_source("util/fixture.rs", src);
    assert_eq!(
        findings.len(),
        1,
        "want exactly the production wall-clock read: {:?}",
        findings.iter().map(|f| f.render()).collect::<Vec<_>>()
    );
    assert_eq!(findings[0].rule_id, "D004");
    let worst = src.lines().position(|l| l.contains("elapsed")).unwrap() + 1;
    assert_eq!(findings[0].line, worst, "finding must sit below the balanced test items");
}

#[test]
fn scoping_is_per_module() {
    // hash structures are fine outside the determinism-critical dirs
    let src = "pub fn f() { let _ = std::collections::HashMap::<u32, u32>::new(); }\n";
    assert!(lint_source("data/free.rs", src).is_empty());
    assert!(!lint_source("engine/hot.rs", src).is_empty());
    // the timing harness may read the clock
    let src = "pub fn f() { let _ = std::time::Instant::now(); }\n";
    assert!(lint_source("util/benchkit.rs", src).is_empty());
    assert!(lint_source("harness/bench.rs", src).is_empty());
    assert!(!lint_source("engine/hot.rs", src).is_empty());
    // ...and so may the node transport edge (socket dial deadlines and
    // reconnect backoff), but the rest of node/ stays deterministic:
    // wall-clock use outside the transport file still trips D004
    assert!(lint_source("node/transport.rs", src).is_empty());
    assert!(!lint_source("node/daemon.rs", src).is_empty());
    assert!(!lint_source("node/controller.rs", src).is_empty());
    // util/order.rs is the one place raw partial_cmp may live
    let src = "pub fn f(a: f64, b: f64) { let _ = a.partial_cmp(&b); }\n";
    assert!(lint_source("util/order.rs", src).is_empty());
    assert!(!lint_source("util/mat.rs", src).is_empty());
    // raw thread spawns are fine only inside the pool module
    let src = "pub fn f() { std::thread::spawn(|| {}); }\n";
    assert!(lint_source("runtime/pool.rs", src).is_empty());
    assert!(!lint_source("runtime/native.rs", src).is_empty());
    assert!(!lint_source("sweep/mod.rs", src).is_empty());
}

#[test]
fn unsafe_allowlist_scoping() {
    // a well-contracted unsafe block is fine only in the two audited
    // files; anywhere else it is a containment breach (D008)
    let src = "pub fn f(p: *mut f32) -> f32 {\n    \
               // SAFETY: caller guarantees `p` is live and aligned here.\n    \
               unsafe { *p }\n}\n";
    assert!(lint_source("util/simd.rs", src).is_empty());
    assert!(lint_source("runtime/pool.rs", src).is_empty());
    let breach = lint_source("engine/hot.rs", src);
    assert!(breach.iter().any(|f| f.rule_id == "D008"), "containment breach not flagged");
    assert!(breach.iter().all(|f| f.rule_id == "D008"), "contracted unsafe tripped more");
    // the xtask sources are scanned under their full prefix, so the
    // allowlist can never match them
    assert!(lint_source("xtask/src/lint.rs", src).iter().any(|f| f.rule_id == "D008"));
}

#[test]
fn relaxed_is_confined_to_the_pool() {
    let src = "use std::sync::atomic::{AtomicUsize, Ordering};\n\
               pub fn f(c: &AtomicUsize) -> usize {\n    \
               // ordering: Relaxed — monotonic counter, no ordering needed\n    \
               c.load(Ordering::Relaxed)\n}\n";
    // annotated Relaxed is legal inside the pool, and nowhere else
    assert!(lint_source("runtime/pool.rs", src).is_empty());
    let outside = lint_source("engine/hot.rs", src);
    assert_eq!(outside.len(), 1, "{:?}", outside.iter().map(|f| f.render()).collect::<Vec<_>>());
    assert_eq!(outside[0].rule_id, "D010");
}

#[test]
fn safety_contract_window_is_three_lines() {
    // marker exactly 3 lines above the `unsafe` token: in the window
    let near = "pub fn f(p: *mut f32) -> f32 {\n    \
                // SAFETY: caller guarantees `p` is live and aligned here.\n    \
                //\n    \
                //\n    \
                unsafe { *p }\n}\n";
    assert!(lint_source("util/simd.rs", near).is_empty());
    // marker 4 lines above: out of the window, the contract is missing
    let far = "pub fn f(p: *mut f32) -> f32 {\n    \
               // SAFETY: caller guarantees `p` is live and aligned here.\n    \
               //\n    \
               //\n    \
               //\n    \
               unsafe { *p }\n}\n";
    let findings = lint_source("util/simd.rs", far);
    assert_eq!(findings.len(), 1, "{:?}", findings.iter().map(|f| f.render()).collect::<Vec<_>>());
    assert_eq!(findings[0].rule_id, "D009");
}

#[test]
fn xtask_sources_are_scanned_too() {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .to_path_buf();
    let files = xtask::lint::scanned_files(&root).expect("walk scan roots");
    let displays: Vec<&str> = files.iter().map(|(_, d)| d.as_str()).collect();
    assert!(displays.contains(&"xtask/src/lint.rs"), "self-lint root missing: {displays:?}");
    assert!(displays.contains(&"rust/src/runtime/pool.rs"), "crate root missing: {displays:?}");
}

#[test]
fn whole_tree_is_clean() {
    // the real rust/src must lint clean — CI runs `cargo xtask verify`,
    // and this keeps `cargo test` equivalent
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .to_path_buf();
    let findings = xtask::lint::run(&root).expect("lint walk");
    assert!(
        findings.is_empty(),
        "rust/src has lint findings:\n{}",
        findings.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
    );
}
